//! Reference ops — the Rust mirror of `python/compile/kernels/ref.py`.
//!
//! Used by the numerics validator (§V-C) to check PJRT artifact outputs, and
//! by the serving integration tests as ground truth. All row-major f32.
//!
//! Ops whose access pattern is driven by *request data* (embedding indices)
//! return `Result`: a malformed request must surface as a rejected inference,
//! never as a panic in the serving hot path.

use crate::util::error::{bail, Result};
use crate::util::threadpool::ThreadPool;
use std::sync::mpsc;
use std::sync::{Arc, OnceLock};

/// Multiply-add count above which `fc` tiles its output rows across the
/// shared kernel pool. Small GEMMs (DLRM dense layers at serving batch
/// sizes) stay on the caller's thread — the fan-out overhead would dominate;
/// big ones (XLM-R projections/FFN at batch×seq rows) parallelize.
const FC_PARALLEL_MIN_MADDS: usize = 1 << 22;

/// Shared pool for intra-kernel tiling (sized to the host, created lazily).
/// Jobs are leaf work — they never submit further jobs — so kernels called
/// from serving worker threads cannot deadlock on it.
fn kernel_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool::new(threads.clamp(2, 8))
    })
}

/// Register-tile dimensions of the blocked GEMM micro-kernel: MR rows of x
/// against NR rows of w accumulate in an MR×NR register block while both
/// operands stream sequentially through K. Every loaded x element is reused
/// NR times and every w element MR times, lifting arithmetic intensity
/// ~MR·NR/(MR+NR)× over the naive loop — without changing the per-element
/// accumulation order (t stays innermost and ascending), so blocked results
/// are bit-identical to the naive reference.
const GEMM_MR: usize = 4;
const GEMM_NR: usize = 4;

/// Output-channel register-tile width of the blocked convolution: for one
/// output pixel, CONV_NR adjacent channels accumulate together so each
/// input activation is loaded once and the weight reads become stride-1
/// (the HWIO layout is contiguous in `cout`).
const CONV_NR: usize = 8;

/// y = x @ w^T + b. x: [m,k], w: [n,k], b: [n] → y: [m,n].
///
/// Large calls are tiled across output rows on [`kernel_pool`] (the
/// ROADMAP's "parallelism inside single kernels" item). Each output element
/// is computed by exactly the same accumulation loop as [`fc_serial`], so
/// the result is bit-identical regardless of tile count — the determinism
/// the §V-C validation story depends on.
pub fn fc(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), n * k);
    assert_eq!(b.len(), n);
    let tiles = kernel_pool().threads().min(m);
    if m * k * n < FC_PARALLEL_MIN_MADDS || tiles < 2 {
        return fc_serial(x, w, b, m, k, n);
    }
    // Jobs must be 'static: share one copy of w/b by Arc and give each tile
    // its own rows of x. One O(m·k + n·k) copy per call, amortized by the
    // O(m·k·n) GEMM this branch only runs for.
    let w = Arc::new(w.to_vec());
    let b = Arc::new(b.to_vec());
    let chunk = m.div_ceil(tiles);
    let (tx, rx) = mpsc::channel::<(usize, Vec<f32>)>();
    let mut submitted = 0usize;
    for t in 0..tiles {
        let (r0, r1) = (t * chunk, ((t + 1) * chunk).min(m));
        if r0 >= r1 {
            continue;
        }
        let xt = x[r0 * k..r1 * k].to_vec();
        let (w, b, tx) = (Arc::clone(&w), Arc::clone(&b), tx.clone());
        kernel_pool().execute(move || {
            let _ = tx.send((r0, fc_serial(&xt, &w, &b, r1 - r0, k, n)));
        });
        submitted += 1;
    }
    drop(tx);
    let mut y = vec![0f32; m * n];
    let mut received = 0usize;
    for (r0, rows) in rx.iter() {
        y[r0 * n..r0 * n + rows.len()].copy_from_slice(&rows);
        received += 1;
    }
    assert_eq!(received, submitted, "fc tile worker exited without reporting");
    y
}

/// Single-thread reference `fc` — the fallback for small GEMMs and the
/// per-tile kernel of the parallel path (so both compute identical bits).
/// Cache-blocked via [`fc_into`]; bit-identical to the naive loop.
pub fn fc_serial(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0f32; m * n];
    fc_into(x, w, b, m, k, n, &mut y);
    y
}

/// Blocked `fc` writing into a caller-provided buffer — the serving hot
/// path's allocation-free entry point (buffers come from the per-worker
/// [`crate::numerics::arena::Arena`]).
pub fn fc_into(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize, y: &mut [f32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), n * k);
    assert_eq!(b.len(), n);
    assert_eq!(y.len(), m * n);
    let mb = m - m % GEMM_MR;
    let nb = n - n % GEMM_NR;
    for ib in (0..mb).step_by(GEMM_MR) {
        for jb in (0..nb).step_by(GEMM_NR) {
            let mut acc = [[0f32; GEMM_NR]; GEMM_MR];
            for t in 0..k {
                let mut xs = [0f32; GEMM_MR];
                let mut ws = [0f32; GEMM_NR];
                for (ii, v) in xs.iter_mut().enumerate() {
                    *v = x[(ib + ii) * k + t];
                }
                for (jj, v) in ws.iter_mut().enumerate() {
                    *v = w[(jb + jj) * k + t];
                }
                for ii in 0..GEMM_MR {
                    for jj in 0..GEMM_NR {
                        acc[ii][jj] += xs[ii] * ws[jj];
                    }
                }
            }
            for ii in 0..GEMM_MR {
                for jj in 0..GEMM_NR {
                    y[(ib + ii) * n + jb + jj] = acc[ii][jj] + b[jb + jj];
                }
            }
        }
        fc_naive_into(x, w, b, ib, ib + GEMM_MR, nb, n, k, n, y);
    }
    fc_naive_into(x, w, b, mb, m, 0, n, k, n, y);
}

/// Naive edge loop for the row/column remainders of [`fc_into`] — same
/// t-ascending accumulation as the register tile, so edges match too.
#[allow(clippy::too_many_arguments)]
fn fc_naive_into(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
) {
    for i in i0..i1 {
        let xi = &x[i * k..(i + 1) * k];
        for j in j0..j1 {
            let wj = &w[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for t in 0..k {
                acc += xi[t] * wj[t];
            }
            y[i * n + j] = acc + b[j];
        }
    }
}

/// Quantized FC matching `ref.quant_fc`: dynamic symmetric activation
/// quantization + int32 GEMM + float epilogue.
#[allow(clippy::too_many_arguments)]
pub fn quant_fc(
    x: &[f32],
    wq: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let mut xq = Vec::new();
    let mut y = vec![0f32; m * n];
    quant_fc_into(x, wq, scale, zp, bias, m, k, n, &mut xq, &mut y);
    y
}

/// Blocked `quant_fc` writing into caller buffers: `xq` is a reusable
/// activation-quantization scratch (cleared and refilled; zero-alloc once
/// its capacity has converged), `y` the [m,n] output. Same MR×NR register
/// tile as [`fc_into`] over i32 accumulators; the float epilogue
/// `(acc + rowsum·zp)·(xs·scale) + bias` is evaluated in exactly the
/// reference order, so results are bit-identical to the naive loop.
#[allow(clippy::too_many_arguments)]
pub fn quant_fc_into(
    x: &[f32],
    wq: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    xq: &mut Vec<i32>,
    y: &mut [f32],
) {
    assert_eq!(x.len(), m * k);
    assert_eq!(wq.len(), n * k);
    assert_eq!(scale.len(), n);
    assert_eq!(zp.len(), n);
    assert_eq!(bias.len(), n);
    assert_eq!(y.len(), m * n);
    let absmax = x.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-8);
    let xs = absmax / 127.0;
    xq.clear();
    xq.extend(x.iter().map(|&v| (v / xs).round().clamp(-127.0, 127.0) as i32));
    let mb = m - m % GEMM_MR;
    let nb = n - n % GEMM_NR;
    for ib in (0..mb).step_by(GEMM_MR) {
        let mut rowsum = [0i32; GEMM_MR];
        for (ii, rs) in rowsum.iter_mut().enumerate() {
            *rs = xq[(ib + ii) * k..(ib + ii + 1) * k].iter().sum();
        }
        for jb in (0..nb).step_by(GEMM_NR) {
            let mut acc = [[0i32; GEMM_NR]; GEMM_MR];
            for t in 0..k {
                let mut xs_t = [0i32; GEMM_MR];
                let mut ws_t = [0i32; GEMM_NR];
                for (ii, v) in xs_t.iter_mut().enumerate() {
                    *v = xq[(ib + ii) * k + t];
                }
                for (jj, v) in ws_t.iter_mut().enumerate() {
                    *v = wq[(jb + jj) * k + t] as i32;
                }
                for ii in 0..GEMM_MR {
                    for jj in 0..GEMM_NR {
                        acc[ii][jj] += xs_t[ii] * ws_t[jj];
                    }
                }
            }
            for ii in 0..GEMM_MR {
                for jj in 0..GEMM_NR {
                    let j = jb + jj;
                    let acc_f = acc[ii][jj] as f32 + rowsum[ii] as f32 * zp[j];
                    y[(ib + ii) * n + j] = acc_f * (xs * scale[j]) + bias[j];
                }
            }
        }
        quant_fc_naive_into(xq, wq, scale, zp, bias, ib, ib + GEMM_MR, nb, n, k, n, xs, y);
    }
    quant_fc_naive_into(xq, wq, scale, zp, bias, mb, m, 0, n, k, n, xs, y);
}

/// Naive edge loop for the remainders of [`quant_fc_into`].
#[allow(clippy::too_many_arguments)]
fn quant_fc_naive_into(
    xq: &[i32],
    wq: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    k: usize,
    n: usize,
    xs: f32,
    y: &mut [f32],
) {
    for i in i0..i1 {
        let row = &xq[i * k..(i + 1) * k];
        let rowsum: i32 = row.iter().sum();
        for j in j0..j1 {
            let wj = &wq[j * k..(j + 1) * k];
            let mut acc: i32 = 0;
            for t in 0..k {
                acc += row[t] * wj[t] as i32;
            }
            let acc_f = acc as f32 + rowsum as f32 * zp[j];
            y[i * n + j] = acc_f * (xs * scale[j]) + bias[j];
        }
    }
}

/// SparseLengthsSum: table [rows, dim], indices [batch, max_len],
/// lengths [batch] → pooled [batch, dim]. Tail indices are masked.
///
/// Indices and lengths come straight from the request, so they are data,
/// not contract: an out-of-range (or negative) index is an `Err`, not a
/// panic. Shapes are contract (pre-validated by the engine) and stay
/// asserts.
pub fn sls(
    table: &[f32],
    dim: usize,
    indices: &[i32],
    lengths: &[i32],
    batch: usize,
    max_len: usize,
) -> Result<Vec<f32>> {
    let mut out = vec![0f32; batch * dim];
    sls_into(table, dim, indices, lengths, batch, max_len, &mut out)?;
    Ok(out)
}

/// `sls` writing into a caller-provided `[batch, dim]` slice — the
/// zero-allocation form used by the serving hot path. Rows stream
/// row-major into the pooled output (sequential reads of the table row,
/// sequential accumulate into the batch row); indices are bounds-checked
/// in place with no temporaries. On `Err` the output contents are
/// unspecified (the request is rejected and the buffer recycled).
pub fn sls_into(
    table: &[f32],
    dim: usize,
    indices: &[i32],
    lengths: &[i32],
    batch: usize,
    max_len: usize,
    out: &mut [f32],
) -> Result<()> {
    assert_eq!(indices.len(), batch * max_len);
    assert_eq!(lengths.len(), batch);
    assert_eq!(out.len(), batch * dim);
    let rows = table.len() / dim;
    out.fill(0.0);
    for b in 0..batch {
        let l = (lengths[b].max(0) as usize).min(max_len);
        let acc = &mut out[b * dim..(b + 1) * dim];
        for j in 0..l {
            let idx = indices[b * max_len + j];
            if idx < 0 || idx as usize >= rows {
                bail!(
                    "sls: embedding index {idx} out of range for table with {rows} rows \
                     (batch row {b}, lookup {j})"
                );
            }
            let idx = idx as usize;
            let row = &table[idx * dim..(idx + 1) * dim];
            for d in 0..dim {
                acc[d] += row[d];
            }
        }
    }
    Ok(())
}

/// SparseLengthsSum over a row-wise int8 table (fbgemm-style): each looked
/// up row dequantizes on the fly as `(q + zp[r]) · scale[r]` and streams
/// into the f32 accumulator — the table stays int8 in memory (4× fewer
/// bytes through the cache hierarchy than f32), which is where the SLS
/// speedup comes from since pooling is memory-bound.
#[allow(clippy::too_many_arguments)]
pub fn sls_q8_into(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    dim: usize,
    indices: &[i32],
    lengths: &[i32],
    batch: usize,
    max_len: usize,
    out: &mut [f32],
) -> Result<()> {
    assert_eq!(indices.len(), batch * max_len);
    assert_eq!(lengths.len(), batch);
    assert_eq!(out.len(), batch * dim);
    let rows = q.len() / dim;
    assert_eq!(scale.len(), rows);
    assert_eq!(zp.len(), rows);
    out.fill(0.0);
    for b in 0..batch {
        let l = (lengths[b].max(0) as usize).min(max_len);
        let acc = &mut out[b * dim..(b + 1) * dim];
        for j in 0..l {
            let idx = indices[b * max_len + j];
            if idx < 0 || idx as usize >= rows {
                bail!(
                    "sls: embedding index {idx} out of range for table with {rows} rows \
                     (batch row {b}, lookup {j})"
                );
            }
            let idx = idx as usize;
            let row = &q[idx * dim..(idx + 1) * dim];
            let (s, z) = (scale[idx], zp[idx]);
            for d in 0..dim {
                acc[d] += (row[d] as f32 + z) * s;
            }
        }
    }
    Ok(())
}

/// ReLU in place.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.max(0.0);
    }
}

/// Sigmoid in place.
pub fn sigmoid(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = 1.0 / (1.0 + (-*v).exp());
    }
}

/// GeLU (tanh approximation, matching ref.py).
pub fn gelu(x: &mut [f32]) {
    for v in x.iter_mut() {
        let x3 = *v * *v * *v;
        *v = 0.5 * *v * (1.0 + (0.7978845608028654 * (*v + 0.044715 * x3)).tanh());
    }
}

/// LayerNorm over the last dim: x [rows, d].
pub fn layernorm(x: &mut [f32], gamma: &[f32], beta: &[f32], rows: usize, d: usize, eps: f32) {
    for r in 0..rows {
        let row = &mut x[r * d..(r + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for i in 0..d {
            row[i] = (row[i] - mu) * inv * gamma[i] + beta[i];
        }
    }
}

/// Row-wise softmax: x [rows, d].
pub fn softmax(x: &mut [f32], rows: usize, d: usize) {
    for r in 0..rows {
        let row = &mut x[r * d..(r + 1) * d];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut s = 0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
}

/// Scaled dot-product attention over [heads, seq, hd].
pub fn attention(q: &[f32], k: &[f32], v: &[f32], heads: usize, seq: usize, hd: usize) -> Vec<f32> {
    let mut out = vec![0f32; heads * seq * hd];
    let mut scores = vec![0f32; seq * seq];
    attention_into(q, k, v, heads, seq, hd, &mut scores, &mut out);
    out
}

/// `attention` writing into caller buffers: `scores` is a reusable
/// [seq, seq] scratch, `out` the [heads, seq, hd] output — the
/// zero-allocation form for the serving hot path.
#[allow(clippy::too_many_arguments)]
pub fn attention_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    heads: usize,
    seq: usize,
    hd: usize,
    scores: &mut [f32],
    out: &mut [f32],
) {
    assert_eq!(scores.len(), seq * seq);
    assert_eq!(out.len(), heads * seq * hd);
    let scale = 1.0 / (hd as f32).sqrt();
    for h in 0..heads {
        let qh = &q[h * seq * hd..];
        let kh = &k[h * seq * hd..];
        let vh = &v[h * seq * hd..];
        for i in 0..seq {
            for j in 0..seq {
                let mut acc = 0f32;
                for t in 0..hd {
                    acc += qh[i * hd + t] * kh[j * hd + t];
                }
                scores[i * seq + j] = acc * scale;
            }
        }
        softmax(scores, seq, seq);
        for i in 0..seq {
            for t in 0..hd {
                let mut acc = 0f32;
                for j in 0..seq {
                    acc += scores[i * seq + j] * vh[j * hd + t];
                }
                out[h * seq * hd + i * hd + t] = acc;
            }
        }
    }
}

/// DLRM dot interaction (ref.py::dot_interaction): dense [b, d] +
/// sparse [b, f-1, d] → [b, d + f(f-1)/2].
pub fn dot_interaction(dense: &[f32], sparse: &[f32], batch: usize, d: usize, num_sparse: usize) -> Vec<f32> {
    let f = num_sparse + 1;
    let mut out = vec![0f32; batch * (d + f * (f - 1) / 2)];
    let mut feats = vec![0f32; f * d];
    dot_interaction_into(dense, sparse, batch, d, num_sparse, &mut feats, &mut out);
    out
}

/// `dot_interaction` writing into caller buffers: `feats` is a reusable
/// [f, d] gather scratch, `out` the [b, d + f(f-1)/2] output — the
/// zero-allocation form for the serving hot path.
pub fn dot_interaction_into(
    dense: &[f32],
    sparse: &[f32],
    batch: usize,
    d: usize,
    num_sparse: usize,
    feats: &mut [f32],
    out: &mut [f32],
) {
    let f = num_sparse + 1;
    let pairs = f * (f - 1) / 2;
    let out_dim = d + pairs;
    assert_eq!(feats.len(), f * d);
    assert_eq!(out.len(), batch * out_dim);
    for b in 0..batch {
        // assemble [f, d]: dense row then sparse rows
        feats[..d].copy_from_slice(&dense[b * d..(b + 1) * d]);
        for s in 0..num_sparse {
            let src = &sparse[(b * num_sparse + s) * d..(b * num_sparse + s + 1) * d];
            feats[(s + 1) * d..(s + 2) * d].copy_from_slice(src);
        }
        let o = &mut out[b * out_dim..(b + 1) * out_dim];
        o[..d].copy_from_slice(&feats[..d]);
        // upper-triangular pairwise dots, (i, j) with i < j, row-major like
        // jnp.triu_indices
        let mut p = d;
        for i in 0..f {
            for j in (i + 1)..f {
                let mut acc = 0f32;
                for t in 0..d {
                    acc += feats[i * d + t] * feats[j * d + t];
                }
                o[p] = acc;
                p += 1;
            }
        }
    }
}

/// 2D convolution, NHWC x HWIO → NHWC, SAME padding.
///
/// Large calls tile their **output channels** across [`kernel_pool`] (same
/// FLOP threshold as [`fc`]); every output element is computed by exactly
/// the accumulation loop of [`conv2d_serial`], so results are bit-identical
/// at any tile count — the CV counterpart of the fc tiling determinism
/// contract.
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    wd: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    groups: usize,
) -> Vec<f32> {
    let oh = h.div_ceil(stride);
    let ow = wd.div_ceil(stride);
    let cing = cin / groups;
    let madds = n * oh * ow * cout * kh * kw * cing;
    let tiles = kernel_pool().threads().min(cout);
    if madds < FC_PARALLEL_MIN_MADDS || tiles < 2 {
        return conv2d_serial(x, w, b, n, h, wd, cin, kh, kw, cout, stride, groups);
    }
    // Jobs must be 'static: share x/w/b by Arc (one copy per call,
    // amortized by the O(madds) work this branch only runs for); each tile
    // computes a contiguous co range and is scattered back channel-wise.
    let x = Arc::new(x.to_vec());
    let w = Arc::new(w.to_vec());
    let b = Arc::new(b.to_vec());
    let chunk = cout.div_ceil(tiles);
    let (tx, rx) = mpsc::channel::<(usize, usize, Vec<f32>)>();
    let mut submitted = 0usize;
    for t in 0..tiles {
        let (c0, c1) = (t * chunk, ((t + 1) * chunk).min(cout));
        if c0 >= c1 {
            continue;
        }
        let (x, w, b, tx) = (Arc::clone(&x), Arc::clone(&w), Arc::clone(&b), tx.clone());
        kernel_pool().execute(move || {
            let tile =
                conv2d_ch_range(&x, &w, &b, n, h, wd, cin, kh, kw, cout, stride, groups, c0, c1);
            let _ = tx.send((c0, c1, tile));
        });
        submitted += 1;
    }
    drop(tx);
    let mut y = vec![0f32; n * oh * ow * cout];
    let mut received = 0usize;
    for (c0, c1, tile) in rx.iter() {
        let span = c1 - c0;
        for pix in 0..n * oh * ow {
            y[pix * cout + c0..pix * cout + c1].copy_from_slice(&tile[pix * span..(pix + 1) * span]);
        }
        received += 1;
    }
    assert_eq!(received, submitted, "conv2d tile worker exited without reporting");
    y
}

/// Single-thread reference `conv2d` — the fallback for small convolutions
/// and the shape the §V-C validation story pins (the tiled path computes
/// identical bits through [`conv2d_ch_range`]).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_serial(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    wd: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    groups: usize,
) -> Vec<f32> {
    // the full-range tile's layout is exactly the full output
    conv2d_ch_range(x, w, b, n, h, wd, cin, kh, kw, cout, stride, groups, 0, cout)
}

/// Serial blocked `conv2d` writing into a caller-provided buffer — the
/// zero-allocation form for the serving hot path.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_into(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    wd: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    groups: usize,
    y: &mut [f32],
) {
    conv2d_ch_range_into(x, w, b, n, h, wd, cin, kh, kw, cout, stride, groups, 0, cout, y);
}

/// One output-channel tile `[co0, co1)` of the convolution, laid out
/// `[n, oh, ow, co1-co0]`. Both the serial and the tiled `conv2d` paths
/// compute every element through this one loop, which is what makes tiling
/// bit-exact: per element the accumulation order never changes.
#[allow(clippy::too_many_arguments)]
fn conv2d_ch_range(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    wd: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    groups: usize,
    co0: usize,
    co1: usize,
) -> Vec<f32> {
    let oh = h.div_ceil(stride);
    let ow = wd.div_ceil(stride);
    let mut y = vec![0f32; n * oh * ow * (co1 - co0)];
    conv2d_ch_range_into(x, w, b, n, h, wd, cin, kh, kw, cout, stride, groups, co0, co1, &mut y);
    y
}

/// Blocked core of the convolution: for each output pixel, [`CONV_NR`]
/// adjacent channels (never crossing a group boundary) accumulate together,
/// so each input activation loads once per channel block and the HWIO
/// weight reads are stride-1 in `co`. Per channel the accumulation order is
/// unchanged — bias then (ky, kx, ci) ascending — keeping results
/// bit-identical to the unblocked loop.
#[allow(clippy::too_many_arguments)]
fn conv2d_ch_range_into(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    wd: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    stride: usize,
    groups: usize,
    co0: usize,
    co1: usize,
    y: &mut [f32],
) {
    let oh = h.div_ceil(stride);
    let ow = wd.div_ceil(stride);
    let cing = cin / groups;
    let coutg = cout / groups;
    let span = co1 - co0;
    assert_eq!(y.len(), n * oh * ow * span);
    // SAME padding offsets
    let pad_h = ((oh - 1) * stride + kh).saturating_sub(h) / 2;
    let pad_w = ((ow - 1) * stride + kw).saturating_sub(wd) / 2;
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut co = co0;
                while co < co1 {
                    let g = co / coutg;
                    // channel block: clipped at the tile end and at the next
                    // group boundary so every channel shares one input slice
                    let ce = (co + CONV_NR).min(co1).min((g + 1) * coutg);
                    let nrr = ce - co;
                    let mut acc = [0f32; CONV_NR];
                    acc[..nrr].copy_from_slice(&b[co..ce]);
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as isize - pad_h as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as isize - pad_w as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            let xbase =
                                ((ni * h + iy as usize) * wd + ix as usize) * cin + g * cing;
                            for ci in 0..cing {
                                let xi = x[xbase + ci];
                                let wbase = ((ky * kw + kx) * cing + ci) * cout + co;
                                for (cc, a) in acc[..nrr].iter_mut().enumerate() {
                                    *a += xi * w[wbase + cc];
                                }
                            }
                        }
                    }
                    let obase = ((ni * oh + oy) * ow + ox) * span + (co - co0);
                    y[obase..obase + nrr].copy_from_slice(&acc[..nrr]);
                    co = ce;
                }
            }
        }
    }
}

/// Global average pool NHWC → [n, c].
pub fn global_avgpool(x: &[f32], n: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut y = vec![0f32; n * c];
    global_avgpool_into(x, n, h, w, c, &mut y);
    y
}

/// `global_avgpool` writing into a caller-provided [n, c] buffer.
pub fn global_avgpool_into(x: &[f32], n: usize, h: usize, w: usize, c: usize, y: &mut [f32]) {
    assert_eq!(y.len(), n * c);
    let inv = 1.0 / (h * w) as f32;
    for ni in 0..n {
        for ci in 0..c {
            let mut acc = 0f32;
            for yi in 0..h {
                for xi in 0..w {
                    acc += x[((ni * h + yi) * w + xi) * c + ci];
                }
            }
            y[ni * c + ci] = acc * inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::quant::quantize_rowwise_int8;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn fc_identity() {
        // w = I, b = 0 -> y = x
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![0.0, 0.0];
        assert_eq!(fc(&x, &w, &b, 2, 2, 2), x);
    }

    #[test]
    fn fc_parallel_bit_identical_to_serial() {
        // large enough to cross FC_PARALLEL_MIN_MADDS -> tiled path
        let (m, k, n) = (64, 256, 512);
        assert!(m * k * n >= FC_PARALLEL_MIN_MADDS);
        let mut rng = Rng::new(11);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        let serial = fc_serial(&x, &w, &b, m, k, n);
        // bitwise equal, and stable across repeated parallel runs
        for _ in 0..3 {
            assert_eq!(fc(&x, &w, &b, m, k, n), serial);
        }
    }

    #[test]
    fn fc_small_falls_back_to_serial() {
        let (m, k, n) = (3, 8, 5);
        let mut rng = Rng::new(13);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        assert_eq!(fc(&x, &w, &b, m, k, n), fc_serial(&x, &w, &b, m, k, n));
    }

    #[test]
    fn fc_parallel_safe_under_concurrent_callers() {
        // serving workers call fc concurrently; tiles from different calls
        // interleave on the shared pool and must not cross-talk
        let (m, k, n) = (64, 256, 512);
        let mut rng = Rng::new(17);
        let x = std::sync::Arc::new(randv(&mut rng, m * k));
        let w = std::sync::Arc::new(randv(&mut rng, n * k));
        let b = std::sync::Arc::new(randv(&mut rng, n));
        let expect = fc_serial(&x, &w, &b, m, k, n);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (x, w, b, e) =
                    (Arc::clone(&x), Arc::clone(&w), Arc::clone(&b), expect.clone());
                std::thread::spawn(move || assert_eq!(fc(&x, &w, &b, m, k, n), e))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn quant_fc_close_to_fp() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (4, 32, 16);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        let q = quantize_rowwise_int8(&w, n, k);
        let yq = quant_fc(&x, &q.q, &q.scale, &q.zp, &b, m, k, n);
        let yf = fc(&x, &w, &b, m, k, n);
        for (a, e) in yq.iter().zip(&yf) {
            assert!((a - e).abs() < 0.35, "{a} vs {e}");
        }
    }

    #[test]
    fn sls_masks_tail() {
        let table = vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]; // 3 rows, dim 2
        let indices = vec![0, 1, 2, 2]; // batch 2, max_len 2
        let lengths = vec![2, 1];
        let out = sls(&table, 2, &indices, &lengths, 2, 2).unwrap();
        assert_eq!(out, vec![3.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn sls_rejects_out_of_range_index() {
        let table = vec![0.0; 3 * 2]; // 3 rows, dim 2
        let indices = vec![0, 3]; // 3 is one past the last row
        let lengths = vec![2];
        let err = sls(&table, 2, &indices, &lengths, 1, 2).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn sls_rejects_negative_index() {
        let table = vec![0.0; 3 * 2];
        let indices = vec![-1, 0];
        let lengths = vec![2];
        assert!(sls(&table, 2, &indices, &lengths, 1, 2).is_err());
    }

    #[test]
    fn sls_masked_tail_index_not_checked() {
        // garbage beyond `lengths[b]` is masked, so it must not error
        let table = vec![1.0, 1.0, 2.0, 2.0];
        let indices = vec![0, 9999];
        let lengths = vec![1];
        let out = sls(&table, 2, &indices, &lengths, 1, 2).unwrap();
        assert_eq!(out, vec![1.0, 1.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn layernorm_normalizes() {
        let mut rng = Rng::new(7);
        let mut x = randv(&mut rng, 4 * 16);
        let g = vec![1.0; 16];
        let b = vec![0.0; 16];
        layernorm(&mut x, &g, &b, 4, 16, 1e-5);
        for r in 0..4 {
            let row = &x[r * 16..(r + 1) * 16];
            let mu: f32 = row.iter().sum::<f32>() / 16.0;
            assert!(mu.abs() < 1e-5, "{mu}");
        }
    }

    #[test]
    fn attention_constant_v() {
        let mut rng = Rng::new(9);
        let (h, s, d) = (2, 8, 4);
        let q = randv(&mut rng, h * s * d);
        let k = randv(&mut rng, h * s * d);
        let v = vec![2.5f32; h * s * d];
        let out = attention(&q, &k, &v, h, s, d);
        for &o in &out {
            assert!((o - 2.5).abs() < 1e-5, "{o}");
        }
    }

    #[test]
    fn dot_interaction_shape_and_dense_passthrough() {
        let mut rng = Rng::new(11);
        let (b, d, ns) = (3, 8, 5);
        let dense = randv(&mut rng, b * d);
        let sparse = randv(&mut rng, b * ns * d);
        let out = dot_interaction(&dense, &sparse, b, d, ns);
        let f = ns + 1;
        assert_eq!(out.len(), b * (d + f * (f - 1) / 2));
        for bi in 0..b {
            let od = d + f * (f - 1) / 2;
            assert_eq!(&out[bi * od..bi * od + d], &dense[bi * d..(bi + 1) * d]);
        }
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 conv with identity weights preserves input
        let x = vec![1.0, 2.0, 3.0, 4.0]; // n1 h2 w2 c1
        let w = vec![1.0]; // 1x1x1x1
        let b = vec![0.0];
        let y = conv2d(&x, &w, &b, 1, 2, 2, 1, 1, 1, 1, 1, 1);
        assert_eq!(y, x);
    }

    #[test]
    fn conv2d_stride_downsamples() {
        let x = vec![1.0; 1 * 4 * 4 * 1];
        let w = vec![1.0];
        let b = vec![0.0];
        let y = conv2d(&x, &w, &b, 1, 4, 4, 1, 1, 1, 1, 2, 1);
        assert_eq!(y.len(), 4); // 2x2
    }

    #[test]
    fn conv2d_parallel_bit_identical_to_serial() {
        // large enough to cross FC_PARALLEL_MIN_MADDS -> tiled path
        let (n, h, wd, cin, cout, k, groups) = (1, 16, 16, 64, 64, 3, 1);
        assert!(n * h * wd * cout * k * k * (cin / groups) >= FC_PARALLEL_MIN_MADDS);
        let mut rng = Rng::new(21);
        let x = randv(&mut rng, n * h * wd * cin);
        let w = randv(&mut rng, k * k * (cin / groups) * cout);
        let b = randv(&mut rng, cout);
        let serial = conv2d_serial(&x, &w, &b, n, h, wd, cin, k, k, cout, 1, groups);
        // bitwise equal, and stable across repeated parallel runs
        for _ in 0..3 {
            assert_eq!(conv2d(&x, &w, &b, n, h, wd, cin, k, k, cout, 1, groups), serial);
        }
    }

    #[test]
    fn conv2d_grouped_strided_parallel_matches_serial() {
        // grouped conv with stride, above the threshold: tile boundaries
        // cut across groups and the strided output grid
        let (n, h, wd, cin, cout, k, groups, stride) = (1, 32, 32, 128, 128, 3, 8, 2);
        let (oh, ow) = (h.div_ceil(stride), wd.div_ceil(stride));
        assert!(n * oh * ow * cout * k * k * (cin / groups) >= FC_PARALLEL_MIN_MADDS);
        let mut rng = Rng::new(23);
        let x = randv(&mut rng, n * h * wd * cin);
        let w = randv(&mut rng, k * k * (cin / groups) * cout);
        let b = randv(&mut rng, cout);
        let serial = conv2d_serial(&x, &w, &b, n, h, wd, cin, k, k, cout, stride, groups);
        assert_eq!(conv2d(&x, &w, &b, n, h, wd, cin, k, k, cout, stride, groups), serial);
        // an unaligned channel tile agrees element-wise with the full run
        let tile = conv2d_ch_range(&x, &w, &b, n, h, wd, cin, k, k, cout, stride, groups, 3, 11);
        for pix in 0..n * oh * ow {
            assert_eq!(&tile[pix * 8..(pix + 1) * 8], &serial[pix * cout + 3..pix * cout + 11]);
        }
    }

    #[test]
    fn conv2d_small_falls_back_to_serial() {
        let (n, h, wd, cin, cout) = (1, 4, 4, 3, 5);
        let mut rng = Rng::new(25);
        let x = randv(&mut rng, n * h * wd * cin);
        let w = randv(&mut rng, 3 * 3 * cin * cout);
        let b = randv(&mut rng, cout);
        assert_eq!(
            conv2d(&x, &w, &b, n, h, wd, cin, 3, 3, cout, 1, 1),
            conv2d_serial(&x, &w, &b, n, h, wd, cin, 3, 3, cout, 1, 1)
        );
    }

    #[test]
    fn global_avgpool_means() {
        let x = vec![1.0, 3.0, 5.0, 7.0]; // n1 h2 w2 c1
        let y = global_avgpool(&x, 1, 2, 2, 1);
        assert_eq!(y, vec![4.0]);
    }

    #[test]
    fn gelu_matches_known_values() {
        let mut x = vec![0.0f32, 1.0, -1.0];
        gelu(&mut x);
        assert!(x[0].abs() < 1e-7);
        assert!((x[1] - 0.8412).abs() < 1e-3, "{}", x[1]);
        assert!((x[2] + 0.1588).abs() < 1e-3, "{}", x[2]);
    }

    // ---- blocked-kernel determinism: the pre-blocking naive loops live on
    // here as oracles; the register-tiled kernels must match them
    // bit-for-bit on every shape, including the remainder paths ----

    fn fc_naive(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for t in 0..k {
                    acc += x[i * k + t] * w[j * k + t];
                }
                y[i * n + j] = acc + b[j];
            }
        }
        y
    }

    #[allow(clippy::too_many_arguments)]
    fn quant_fc_naive(
        x: &[f32],
        wq: &[i8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let absmax = x.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-8);
        let xs = absmax / 127.0;
        let xq: Vec<i32> =
            x.iter().map(|&v| (v / xs).round().clamp(-127.0, 127.0) as i32).collect();
        let mut y = vec![0f32; m * n];
        for i in 0..m {
            let rowsum: i32 = xq[i * k..(i + 1) * k].iter().sum();
            for j in 0..n {
                let mut acc: i32 = 0;
                for t in 0..k {
                    acc += xq[i * k + t] * wq[j * k + t] as i32;
                }
                let acc_f = acc as f32 + rowsum as f32 * zp[j];
                y[i * n + j] = acc_f * (xs * scale[j]) + bias[j];
            }
        }
        y
    }

    #[test]
    fn fc_blocked_bit_identical_to_naive_on_odd_shapes() {
        // covers: m=1 latency shapes, K not a multiple of any block, n
        // smaller than the register tile, and exact-tile shapes
        let shapes = [(1, 37, 5), (1, 256, 1), (3, 8, 5), (4, 64, 4), (5, 33, 7), (6, 129, 12)];
        let mut rng = Rng::new(31);
        for &(m, k, n) in &shapes {
            let x = randv(&mut rng, m * k);
            let w = randv(&mut rng, n * k);
            let b = randv(&mut rng, n);
            assert_eq!(fc_serial(&x, &w, &b, m, k, n), fc_naive(&x, &w, &b, m, k, n), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn fc_into_matches_fc_serial() {
        let (m, k, n) = (7, 41, 13);
        let mut rng = Rng::new(33);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        let mut y = vec![0f32; m * n];
        fc_into(&x, &w, &b, m, k, n, &mut y);
        assert_eq!(y, fc_serial(&x, &w, &b, m, k, n));
    }

    #[test]
    fn quant_fc_blocked_bit_identical_to_naive_on_odd_shapes() {
        let shapes = [(1, 37, 5), (4, 32, 16), (5, 13, 9), (8, 64, 3)];
        let mut rng = Rng::new(35);
        for &(m, k, n) in &shapes {
            let x = randv(&mut rng, m * k);
            let w = randv(&mut rng, n * k);
            let b = randv(&mut rng, n);
            let q = quantize_rowwise_int8(&w, n, k);
            assert_eq!(
                quant_fc(&x, &q.q, &q.scale, &q.zp, &b, m, k, n),
                quant_fc_naive(&x, &q.q, &q.scale, &q.zp, &b, m, k, n),
                "{m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn quant_fc_into_scratch_converges() {
        // the xq scratch must stop growing after the first call — the
        // zero-allocation contract of the serving hot path
        let (m, k, n) = (4, 32, 8);
        let mut rng = Rng::new(37);
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        let q = quantize_rowwise_int8(&w, n, k);
        let mut xq = Vec::new();
        let mut y = vec![0f32; m * n];
        quant_fc_into(&x, &q.q, &q.scale, &q.zp, &b, m, k, n, &mut xq, &mut y);
        let cap = xq.capacity();
        quant_fc_into(&x, &q.q, &q.scale, &q.zp, &b, m, k, n, &mut xq, &mut y);
        assert_eq!(xq.capacity(), cap);
        assert_eq!(y, quant_fc(&x, &q.q, &q.scale, &q.zp, &b, m, k, n));
    }

    #[test]
    fn sls_into_matches_sls_and_clears_stale_output() {
        let table = vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0];
        let indices = vec![0, 1, 2, 2];
        let lengths = vec![2, 1];
        let mut out = vec![99.0f32; 4]; // stale recycled contents
        sls_into(&table, 2, &indices, &lengths, 2, 2, &mut out).unwrap();
        assert_eq!(out, sls(&table, 2, &indices, &lengths, 2, 2).unwrap());
    }

    #[test]
    fn sls_q8_close_to_f32() {
        let mut rng = Rng::new(39);
        let (rows, dim) = (50, 16);
        let table = randv(&mut rng, rows * dim);
        let q = quantize_rowwise_int8(&table, rows, dim);
        let indices: Vec<i32> = (0..8).map(|_| (rng.next_u64() % rows as u64) as i32).collect();
        let lengths = vec![4, 4];
        let f32_out = sls(&table, dim, &indices, &lengths, 2, 4).unwrap();
        let mut q_out = vec![0f32; 2 * dim];
        sls_q8_into(&q.q, &q.scale, &q.zp, dim, &indices, &lengths, 2, 4, &mut q_out).unwrap();
        for (a, e) in q_out.iter().zip(&f32_out) {
            // 4 lookups, each within half an int8 LSB of a unit-normal row
            assert!((a - e).abs() < 0.05, "{a} vs {e}");
        }
    }

    #[test]
    fn sls_q8_rejects_out_of_range_index() {
        let q = quantize_rowwise_int8(&[0.0; 6], 3, 2);
        let mut out = vec![0f32; 2];
        let err = sls_q8_into(&q.q, &q.scale, &q.zp, 2, &[0, 3], &[2], 1, 2, &mut out).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn conv2d_blocked_channel_tile_boundaries() {
        // cout below, straddling, and far above CONV_NR; grouped so channel
        // blocks are clipped at group boundaries (cout/groups = 3 < CONV_NR)
        let cases = [(5usize, 1usize), (9, 1), (12, 3), (20, 2)];
        let mut rng = Rng::new(41);
        for &(cout, groups) in &cases {
            let (n, h, wd, cin, k) = (1, 5, 5, groups * 2, 3);
            let x = randv(&mut rng, n * h * wd * cin);
            let w = randv(&mut rng, k * k * (cin / groups) * cout);
            let b = randv(&mut rng, cout);
            let y = conv2d_serial(&x, &w, &b, n, h, wd, cin, k, k, cout, 1, groups);
            // oracle: per-channel naive accumulation (bias, then ky/kx/ci)
            let cing = cin / groups;
            let coutg = cout / groups;
            let pad = (k - 1) / 2;
            for oy in 0..h {
                for ox in 0..wd {
                    for co in 0..cout {
                        let g = co / coutg;
                        let mut acc = b[co];
                        for ky in 0..k {
                            let iy = (oy + ky) as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = (ox + kx) as isize - pad as isize;
                                if ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                for ci in 0..cing {
                                    let xi = x[((iy as usize) * wd + ix as usize) * cin
                                        + g * cing
                                        + ci];
                                    acc += xi * w[((ky * k + kx) * cing + ci) * cout + co];
                                }
                            }
                        }
                        assert_eq!(y[(oy * wd + ox) * cout + co], acc, "cout {cout} co {co}");
                    }
                }
            }
        }
    }

    #[test]
    fn conv2d_into_matches_serial() {
        let (n, h, wd, cin, cout) = (1, 6, 6, 4, 7);
        let mut rng = Rng::new(43);
        let x = randv(&mut rng, n * h * wd * cin);
        let w = randv(&mut rng, 3 * 3 * cin * cout);
        let b = randv(&mut rng, cout);
        let mut y = vec![0f32; n * h * wd * cout];
        conv2d_into(&x, &w, &b, n, h, wd, cin, 3, 3, cout, 1, 1, &mut y);
        assert_eq!(y, conv2d_serial(&x, &w, &b, n, h, wd, cin, 3, 3, cout, 1, 1));
    }

    #[test]
    fn attention_into_matches_attention() {
        let mut rng = Rng::new(45);
        let (h, s, d) = (2, 6, 4);
        let q = randv(&mut rng, h * s * d);
        let k = randv(&mut rng, h * s * d);
        let v = randv(&mut rng, h * s * d);
        let mut scores = vec![0f32; s * s];
        let mut out = vec![0f32; h * s * d];
        attention_into(&q, &k, &v, h, s, d, &mut scores, &mut out);
        assert_eq!(out, attention(&q, &k, &v, h, s, d));
    }

    #[test]
    fn dot_interaction_into_matches() {
        let mut rng = Rng::new(47);
        let (b, d, ns) = (2, 4, 3);
        let dense = randv(&mut rng, b * d);
        let sparse = randv(&mut rng, b * ns * d);
        let f = ns + 1;
        let mut feats = vec![0f32; f * d];
        let mut out = vec![0f32; b * (d + f * (f - 1) / 2)];
        dot_interaction_into(&dense, &sparse, b, d, ns, &mut feats, &mut out);
        assert_eq!(out, dot_interaction(&dense, &sparse, b, d, ns));
    }
}
