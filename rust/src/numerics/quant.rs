//! Row-wise int8 quantization — the exact scheme of
//! `python/compile/kernels/ref.py::quantize_rowwise_int8` (§V-B), so the
//! Rust-generated quantized weights match what the AOT artifacts expect.

/// Row-wise quantized matrix: q[(r, c)] reconstructs as (q + zp[r]) * scale[r].
#[derive(Debug, Clone)]
pub struct RowwiseInt8 {
    pub q: Vec<i8>,
    pub rows: usize,
    pub cols: usize,
    pub scale: Vec<f32>,
    pub zp: Vec<f32>,
}

/// Quantize a row-major [rows, cols] f32 matrix per-row (asymmetric, 8-bit).
pub fn quantize_rowwise_int8(w: &[f32], rows: usize, cols: usize) -> RowwiseInt8 {
    assert_eq!(w.len(), rows * cols);
    let mut q = vec![0i8; rows * cols];
    let mut scale = vec![0f32; rows];
    let mut zp = vec![0f32; rows];
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let mut wmin = 0f32;
        let mut wmax = 0f32;
        for &v in row {
            wmin = wmin.min(v);
            wmax = wmax.max(v);
        }
        // The epsilon clamp keeps all-zero / constant rows non-degenerate:
        // wmax == wmin would otherwise give scale 0 and an infinite
        // zero-point, poisoning every dequantized value with NaN.
        let s = ((wmax - wmin) / 255.0).max(1e-8);
        let z = (wmin / s).round() + 128.0;
        debug_assert!(s.is_finite() && s > 0.0, "int8 row {r}: degenerate scale {s}");
        debug_assert!(z.is_finite(), "int8 row {r}: degenerate zero-point {z}");
        scale[r] = s;
        zp[r] = z;
        for (c, &v) in row.iter().enumerate() {
            let qv = (v / s - z).round().clamp(-128.0, 127.0);
            q[r * cols + c] = qv as i8;
        }
    }
    RowwiseInt8 { q, rows, cols, scale, zp }
}

/// Dequantize back to f32.
pub fn dequantize_rowwise_int8(m: &RowwiseInt8) -> Vec<f32> {
    let mut out = vec![0f32; m.rows * m.cols];
    for r in 0..m.rows {
        for c in 0..m.cols {
            out[r * m.cols + c] = (m.q[r * m.cols + c] as f32 + m.zp[r]) * m.scale[r];
        }
    }
    out
}

/// 4-bit row-wise quantization for embedding tables ([18] in the paper;
/// §V-B "mixed int8/int4"). Values pack two per byte; per-row scale+bias.
#[derive(Debug, Clone)]
pub struct RowwiseInt4 {
    pub packed: Vec<u8>,
    pub rows: usize,
    pub cols: usize,
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
}

pub fn quantize_rowwise_int4(w: &[f32], rows: usize, cols: usize) -> RowwiseInt4 {
    assert_eq!(w.len(), rows * cols);
    let stride = cols.div_ceil(2);
    let mut packed = vec![0u8; rows * stride];
    let mut scale = vec![0f32; rows];
    let mut bias = vec![0f32; rows];
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in row {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        // Same degenerate-row guard as the int8 path: constant rows hit
        // hi == lo and must still produce a positive finite scale.
        let s = ((hi - lo) / 15.0).max(1e-8);
        debug_assert!(s.is_finite() && s > 0.0, "int4 row {r}: degenerate scale {s}");
        debug_assert!(lo.is_finite(), "int4 row {r}: degenerate bias {lo}");
        scale[r] = s;
        bias[r] = lo;
        for c in 0..cols {
            let qv = ((row[c] - lo) / s).round().clamp(0.0, 15.0) as u8;
            let idx = r * stride + c / 2;
            if c % 2 == 0 {
                packed[idx] |= qv;
            } else {
                packed[idx] |= qv << 4;
            }
        }
    }
    RowwiseInt4 { packed, rows, cols, scale, bias }
}

pub fn dequantize_rowwise_int4(m: &RowwiseInt4) -> Vec<f32> {
    let stride = m.cols.div_ceil(2);
    let mut out = vec![0f32; m.rows * m.cols];
    for r in 0..m.rows {
        for c in 0..m.cols {
            let byte = m.packed[r * stride + c / 2];
            let nib = if c % 2 == 0 { byte & 0xf } else { byte >> 4 };
            out[r * m.cols + c] = nib as f32 * m.scale[r] + m.bias[r];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn int8_roundtrip_error_within_half_lsb() {
        let mut rng = Rng::new(1);
        let (r, c) = (17, 33);
        let w = rand_mat(&mut rng, r, c);
        let q = quantize_rowwise_int8(&w, r, c);
        let deq = dequantize_rowwise_int8(&q);
        for row in 0..r {
            for col in 0..c {
                let err = (deq[row * c + col] - w[row * c + col]).abs();
                assert!(err <= 0.75 * q.scale[row], "err {err} scale {}", q.scale[row]);
            }
        }
    }

    #[test]
    fn int8_zero_maps_near_zero() {
        // rows including 0 reconstruct 0 within half an LSB (asymmetric grid)
        let w = vec![0.0, 0.5, 1.0, -0.25];
        let q = quantize_rowwise_int8(&w, 1, 4);
        let deq = dequantize_rowwise_int8(&q);
        assert!(deq[0].abs() <= 0.5 * q.scale[0]);
    }

    #[test]
    fn int8_constant_row() {
        let w = vec![3.5; 8];
        let q = quantize_rowwise_int8(&w, 1, 8);
        let deq = dequantize_rowwise_int8(&q);
        for v in deq {
            assert!((v - 3.5).abs() < 0.05, "{v}");
        }
    }

    #[test]
    fn int8_all_zero_row_is_not_degenerate() {
        // Regression: wmax == wmin == 0 must clamp scale to a positive
        // epsilon (not 0, which would make zp infinite and dequant NaN).
        let w = vec![0.0f32; 16];
        let q = quantize_rowwise_int8(&w, 2, 8);
        for r in 0..2 {
            assert!(q.scale[r] > 0.0 && q.scale[r].is_finite(), "scale {}", q.scale[r]);
            assert!(q.zp[r].is_finite(), "zp {}", q.zp[r]);
        }
        for v in dequantize_rowwise_int8(&q) {
            assert_eq!(v, 0.0); // exact, not merely close
        }
    }

    #[test]
    fn int8_negative_constant_row() {
        // zero-anchored range [-2, 0]: constant negative rows reconstruct
        // within half an LSB and keep zp on the representable grid.
        let w = vec![-2.0f32; 8];
        let q = quantize_rowwise_int8(&w, 1, 8);
        assert!(q.zp[0].is_finite() && q.zp[0].abs() <= 256.0);
        for v in dequantize_rowwise_int8(&q) {
            assert!((v + 2.0).abs() <= 0.5 * q.scale[0], "{v}");
        }
    }

    #[test]
    fn int8_sub_epsilon_range_row_bounded() {
        // Row spread below the epsilon clamp: quantized values must stay in
        // range and reconstruction error stays within one (clamped) LSB.
        let w = vec![1e-9f32, -1e-9, 5e-10, 0.0];
        let q = quantize_rowwise_int8(&w, 1, 4);
        assert_eq!(q.scale[0], 1e-8);
        let deq = dequantize_rowwise_int8(&q);
        for (a, b) in deq.iter().zip(&w) {
            assert!((a - b).abs() <= 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn int4_all_zero_and_constant_rows_not_degenerate() {
        // Same audit for the int4 path: hi == lo rows (all-zero and
        // constant negative) keep a positive scale and finite bias.
        let mut w = vec![0.0f32; 8];
        w.extend_from_slice(&[-1.25f32; 8]);
        let q = quantize_rowwise_int4(&w, 2, 8);
        for r in 0..2 {
            assert!(q.scale[r] > 0.0 && q.scale[r].is_finite());
            assert!(q.bias[r].is_finite());
        }
        let deq = dequantize_rowwise_int4(&q);
        for v in &deq[..8] {
            assert_eq!(*v, 0.0);
        }
        for v in &deq[8..] {
            assert!((v + 1.25).abs() <= q.scale[1], "{v}");
        }
    }

    #[test]
    fn int4_roundtrip_error_within_lsb() {
        let mut rng = Rng::new(2);
        let (r, c) = (9, 15); // odd cols exercise packing
        let w = rand_mat(&mut rng, r, c);
        let q = quantize_rowwise_int4(&w, r, c);
        assert_eq!(q.packed.len(), r * 8);
        let deq = dequantize_rowwise_int4(&q);
        for row in 0..r {
            for col in 0..c {
                let err = (deq[row * c + col] - w[row * c + col]).abs();
                assert!(err <= 0.75 * q.scale[row], "err {err}");
            }
        }
    }

    #[test]
    fn int4_is_coarser_than_int8() {
        let mut rng = Rng::new(3);
        let (r, c) = (4, 64);
        let w = rand_mat(&mut rng, r, c);
        let e8: f32 = dequantize_rowwise_int8(&quantize_rowwise_int8(&w, r, c))
            .iter()
            .zip(&w)
            .map(|(a, b)| (a - b).abs())
            .sum();
        let e4: f32 = dequantize_rowwise_int4(&quantize_rowwise_int4(&w, r, c))
            .iter()
            .zip(&w)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(e4 > 4.0 * e8, "e4 {e4} e8 {e8}");
    }

    #[test]
    fn int4_memory_is_half_of_int8() {
        let w = vec![0.0f32; 10 * 64];
        let q8 = quantize_rowwise_int8(&w, 10, 64);
        let q4 = quantize_rowwise_int4(&w, 10, 64);
        assert_eq!(q4.packed.len() * 2, q8.q.len());
    }
}
