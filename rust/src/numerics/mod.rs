//! Numerics: reference implementations + quantization (§V).
//!
//! The paper's deployment keeps *numeric reference implementations* of every
//! accelerator kernel and validates each vendor release against them
//! (§V-C, the open-sourced FakeLowP tests). Here the "vendor" is the
//! AOT-compiled HLO executed by PJRT, and this module is the independent
//! re-implementation used by `fbia validate-numerics` and the integration
//! tests.

pub mod arena;
pub mod ops_ref;
pub mod quant;
pub mod validate;
pub mod weights;

/// A host-side tensor (row-major). The runtime converts these to/from PJRT
/// literals; the reference ops consume them directly.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    I8(Vec<i8>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) | HostTensor::I8(_, s) => s,
        }
    }

    pub fn elements(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Some(d),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Some(d),
            _ => None,
        }
    }

    pub fn as_i8(&self) -> Option<&[i8]> {
        match self {
            HostTensor::I8(d, _) => Some(d),
            _ => None,
        }
    }

    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32(data, shape.to_vec())
    }

    pub fn i8(data: Vec<i8>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I8(data, shape.to_vec())
    }
}

/// Round an f32 to the nearest f16 and back — models the fp16 storage the
/// card uses for non-quantized weights (§V-B). Round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let mut frac = bits & 0x7f_ffff;
    if exp == 0xff {
        // inf/nan
        let f = if frac != 0 { 0x200 } else { 0 };
        return sign | 0x7c00 | f as u16;
    }
    exp -= 127;
    if exp > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp >= -14 {
        // normal
        let mut mant = frac >> 13;
        let rest = frac & 0x1fff;
        // round to nearest even
        if rest > 0x1000 || (rest == 0x1000 && (mant & 1) == 1) {
            mant += 1;
            if mant == 0x400 {
                mant = 0;
                exp += 1;
                if exp > 15 {
                    return sign | 0x7c00;
                }
            }
        }
        return sign | (((exp + 15) as u16) << 10) | mant as u16;
    }
    // subnormal
    if exp < -25 {
        return sign; // underflow to zero
    }
    frac |= 0x80_0000;
    let shift = (-14 - exp) as u32 + 13;
    let mant0 = frac >> shift;
    let rest = frac & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut mant = mant0;
    if rest > half || (rest == half && (mant & 1) == 1) {
        mant += 1;
    }
    sign | mant as u16
}

/// f16 bits back to f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: value = frac * 2^-24; normalize the leading bit
            let mut k = 0u32;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                k += 1;
            }
            f &= 0x3ff;
            sign | ((113 - k) << 23) | (f << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip through fp16 (the "cast to f16 storage" operation).
pub fn fp16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Apply fp16 rounding to a slice.
pub fn fp16_round_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = fp16_round(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_exact_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0] {
            assert_eq!(fp16_round(v), v, "{v}");
        }
    }

    #[test]
    fn f16_rounding_error_bounded() {
        let mut x = 0.1f32;
        for _ in 0..100 {
            let r = fp16_round(x);
            assert!((r - x).abs() <= x.abs() * 0.001, "{x} -> {r}");
            x *= 1.37;
            if x > 60000.0 {
                break;
            }
        }
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert!(fp16_round(1e20).is_infinite());
        assert!(fp16_round(-1e20).is_infinite());
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 3.0e-8f32;
        let r = fp16_round(tiny);
        assert!(r >= 0.0 && r < 1e-6);
        assert_eq!(fp16_round(1e-12), 0.0);
    }

    #[test]
    fn f16_nan() {
        assert!(fp16_round(f32::NAN).is_nan());
    }

    #[test]
    fn f16_roundtrip_all_half_values() {
        // every finite f16 must round-trip exactly
        for h in 0u16..0x7c00 {
            let f = f16_bits_to_f32(h);
            assert_eq!(f32_to_f16_bits(f), h, "bits {h:#x} -> {f}");
        }
    }

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::f32(vec![1.0, 2.0], &[2]);
        assert_eq!(t.elements(), 2);
        assert!(t.as_f32().is_some());
        assert!(t.as_i8().is_none());
    }
}
