//! Deterministic weight generation for the AOT artifacts.
//!
//! Artifact weights are HLO *parameters*; the coordinator materializes them
//! from a seed, uploads them once per card as device-resident buffers
//! (§VI-C), and the numerics validator re-derives the identical tensors to
//! compute reference outputs. Quantized weight groups (`*_wq/scale/zp`) are
//! derived from one generated fp tensor so the triple stays coherent.

use crate::numerics::quant::{quantize_rowwise_int8, RowwiseInt8};
use crate::numerics::HostTensor;
use crate::runtime::artifact::{Artifact, ArtDType, InputKind, InputSpec};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// FNV-1a hash for per-tensor seeds.
fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The logical fp tensor a spec derives from: `bot_wq0`, `bot_scale0`,
/// `bot_zp0` all map to base `bot_w0`; everything else maps to itself.
fn base_name(name: &str) -> (String, QuantPart) {
    for (tag, part) in [("_wq", QuantPart::Q), ("_scale", QuantPart::Scale), ("_zp", QuantPart::Zp)]
    {
        if let Some(pos) = name.find(tag) {
            let (pre, idx) = name.split_at(pos);
            let idx = &idx[tag.len()..];
            // require a numeric suffix: distinguishes the quantized-group
            // tags (bot_wq0/bot_scale0) from look-alikes such as the XLM-R
            // query projection "l0_wq"
            if !idx.is_empty() && idx.chars().all(|c| c.is_ascii_digit()) {
                return (format!("{pre}_w{idx}"), part);
            }
        }
    }
    (name.to_string(), QuantPart::None)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QuantPart {
    None,
    Q,
    Scale,
    Zp,
}

/// Generate the fp tensor for a base weight name.
fn gen_fp(name: &str, shape: &[usize], seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ fnv(name));
    let n: usize = shape.iter().product();
    // He-style init: std = 1/sqrt(fan_in); embeddings and vectors use 0.1
    let fan_in = if shape.len() >= 2 { shape[shape.len() - 1] } else { 0 };
    let std = if fan_in > 0 { (1.0 / fan_in as f32).sqrt() } else { 0.1 };
    let mut v = vec![0f32; n];
    rng.fill_normal_f32(&mut v, std);
    v
}

/// Generator with a cache of quantized groups.
pub struct WeightGen {
    pub seed: u64,
    quant_cache: HashMap<String, RowwiseInt8>,
}

impl WeightGen {
    pub fn new(seed: u64) -> Self {
        WeightGen { seed, quant_cache: HashMap::new() }
    }

    /// The fp tensor behind a (possibly quantized) weight spec — what the
    /// reference model computes with for non-quantized layers.
    pub fn fp_weight(&self, spec: &InputSpec) -> Vec<f32> {
        let (base, _) = base_name(&spec.name);
        gen_fp(&base, &spec.shape, self.seed)
    }

    fn quant_group(&mut self, base: &str, rows: usize, cols: usize) -> &RowwiseInt8 {
        let seed = self.seed;
        self.quant_cache.entry(base.to_string()).or_insert_with(|| {
            let fp = gen_fp(base, &[rows, cols], seed);
            quantize_rowwise_int8(&fp, rows, cols)
        })
    }

    /// Materialize one weight spec.
    pub fn generate(&mut self, spec: &InputSpec, artifact: &Artifact) -> HostTensor {
        let (base, part) = base_name(&spec.name);
        match part {
            QuantPart::None => {
                debug_assert!(spec.dtype == ArtDType::F32 || spec.dtype == ArtDType::F16);
                HostTensor::f32(gen_fp(&base, &spec.shape, self.seed), &spec.shape)
            }
            QuantPart::Q => {
                let (rows, cols) = (spec.shape[0], spec.shape[1]);
                let q = self.quant_group(&base, rows, cols);
                HostTensor::i8(q.q.clone(), &spec.shape)
            }
            QuantPart::Scale | QuantPart::Zp => {
                // shape [n]; rows/cols come from the matching wq spec
                let wq = artifact
                    .inputs
                    .iter()
                    .find(|s| base_name(&s.name) == (base.clone(), QuantPart::Q))
                    .expect("scale/zp without wq sibling");
                let (rows, cols) = (wq.shape[0], wq.shape[1]);
                let q = self.quant_group(&base, rows, cols);
                let v = if part == QuantPart::Scale { q.scale.clone() } else { q.zp.clone() };
                HostTensor::f32(v, &spec.shape)
            }
        }
    }

    /// All weights of an artifact, in spec order.
    pub fn weights_for(&mut self, artifact: &Artifact) -> Vec<(String, HostTensor)> {
        artifact
            .inputs
            .iter()
            .filter(|s| s.kind != InputKind::Input)
            .map(|s| (s.name.clone(), self.generate(s, artifact)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{ArtDType, InputKind, InputSpec};

    fn spec(name: &str, shape: &[usize], dt: ArtDType, kind: InputKind) -> InputSpec {
        InputSpec { name: name.into(), shape: shape.to_vec(), dtype: dt, kind }
    }

    fn art(inputs: Vec<InputSpec>) -> Artifact {
        Artifact {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            model: "t".into(),
            role: "full".into(),
            batch: 1,
            seq: None,
            shard: None,
            inputs,
            outputs: vec![],
        }
    }

    #[test]
    fn deterministic_across_generators() {
        let s = spec("bot_w0", &[8, 4], ArtDType::F32, InputKind::Weight);
        let a = art(vec![s.clone()]);
        let mut g1 = WeightGen::new(42);
        let mut g2 = WeightGen::new(42);
        assert_eq!(g1.generate(&s, &a), g2.generate(&s, &a));
        let mut g3 = WeightGen::new(43);
        assert_ne!(g1.generate(&s, &a), g3.generate(&s, &a));
    }

    #[test]
    fn quant_group_coherent() {
        // wq/scale/zp must reconstruct the same fp tensor the fp path sees
        let wq = spec("bot_wq0", &[8, 4], ArtDType::I8, InputKind::WeightQ);
        let sc = spec("bot_scale0", &[8], ArtDType::F32, InputKind::Weight);
        let zp = spec("bot_zp0", &[8], ArtDType::F32, InputKind::Weight);
        let fp = spec("bot_w0", &[8, 4], ArtDType::F32, InputKind::Weight);
        let a = art(vec![wq.clone(), sc.clone(), zp.clone()]);
        let mut g = WeightGen::new(7);
        let q = g.generate(&wq, &a);
        let s = g.generate(&sc, &a);
        let z = g.generate(&zp, &a);
        let w = g.fp_weight(&fp);
        // dequantize and compare to the fp tensor
        let qd = q.as_i8().unwrap();
        let sd = s.as_f32().unwrap();
        let zd = z.as_f32().unwrap();
        for r in 0..8 {
            for c in 0..4 {
                let deq = (qd[r * 4 + c] as f32 + zd[r]) * sd[r];
                assert!((deq - w[r * 4 + c]).abs() <= 0.75 * sd[r]);
            }
        }
    }

    #[test]
    fn fan_in_scaling() {
        let wide = spec("w_a", &[4, 4096], ArtDType::F32, InputKind::Weight);
        let narrow = spec("w_b", &[4, 4], ArtDType::F32, InputKind::Weight);
        let a = art(vec![wide.clone(), narrow.clone()]);
        let mut g = WeightGen::new(1);
        let vw = g.generate(&wide, &a);
        let vn = g.generate(&narrow, &a);
        let std = |v: &[f32]| {
            let m: f32 = v.iter().sum::<f32>() / v.len() as f32;
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / v.len() as f32).sqrt()
        };
        assert!(std(vw.as_f32().unwrap()) < 0.1 * std(vn.as_f32().unwrap()));
    }

    #[test]
    fn weights_for_skips_request_inputs() {
        let w = spec("w", &[2, 2], ArtDType::F32, InputKind::Weight);
        let x = spec("x", &[1, 2], ArtDType::F32, InputKind::Input);
        let a = art(vec![w, x]);
        let mut g = WeightGen::new(1);
        let ws = g.weights_for(&a);
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].0, "w");
    }
}
