//! Numerics validation (§V-C): recompute every artifact's outputs with the
//! Rust reference ops and compare against what PJRT produced.
//!
//! The reference models mirror `python/compile/models/*.py` exactly; weights
//! come from the same deterministic generator the runtime uploads, so any
//! disagreement isolates a numerics bug in the artifact/runtime path — the
//! same role the paper's FakeLowP reference implementations play against the
//! vendor kernels.

use crate::numerics::ops_ref as ops;
use crate::numerics::weights::WeightGen;
use crate::numerics::HostTensor;
use crate::runtime::artifact::{Artifact, InputKind, Manifest};
use crate::util::error::{bail, err, Context, Result};
use crate::util::stats::cosine_similarity;
use std::collections::HashMap;
use std::sync::Arc;

/// Comparison outcome for one artifact run.
#[derive(Debug, Clone)]
pub struct Validation {
    pub artifact: String,
    pub max_abs_err: f64,
    pub cosine: f64,
    pub passed: bool,
}

/// Tolerances: fp32 reference vs XLA CPU execution differ only by fma /
/// reassociation; int8 paths are bit-deterministic modulo float epilogue.
pub const ABS_TOL: f64 = 2e-3;
pub const COS_TOL: f64 = 0.999;

/// Compare reference vs runtime outputs.
pub fn compare(artifact: &str, reference: &[f32], measured: &[f32]) -> Validation {
    assert_eq!(reference.len(), measured.len(), "output length mismatch");
    let mut max_abs = 0f64;
    for (r, m) in reference.iter().zip(measured) {
        max_abs = max_abs.max((*r as f64 - *m as f64).abs());
    }
    let cos = cosine_similarity(reference, measured);
    Validation {
        artifact: artifact.to_string(),
        max_abs_err: max_abs,
        cosine: cos,
        passed: max_abs < ABS_TOL || cos > COS_TOL,
    }
}

/// The weight half of an evaluation environment, validated against the
/// artifact spec and indexed by name **once** (at `prepare()` time). Shared
/// by `Arc`, so binding it to a request is a refcount bump — no weight
/// tensor is ever copied on the per-request hot path (the device-resident
/// weights story of §VI-C, host-side).
pub type WeightEnv = Arc<HashMap<String, HostTensor>>;

/// A named-tensor environment for reference evaluation: the shared weight
/// map plus per-request inputs, borrowed from the caller.
pub struct Env<'a> {
    weights: WeightEnv,
    inputs: HashMap<&'a str, &'a HostTensor>,
}

impl<'a> Env<'a> {
    /// Build from an artifact: generated weights + provided request inputs
    /// (in spec order for `kind == Input`).
    pub fn build(
        artifact: &'a Artifact,
        gen: &mut WeightGen,
        inputs: &'a [HostTensor],
    ) -> Result<Env<'a>> {
        let mut weights = HashMap::new();
        let mut req = HashMap::new();
        let mut it = inputs.iter();
        for spec in &artifact.inputs {
            match spec.kind {
                InputKind::Input => {
                    let t = it
                        .next()
                        .ok_or_else(|| err!("missing request input {}", spec.name))?;
                    req.insert(spec.name.as_str(), t);
                }
                _ => {
                    weights.insert(spec.name.clone(), gen.generate(spec, artifact));
                }
            }
        }
        if it.next().is_some() {
            bail!("too many request inputs for {}", artifact.name);
        }
        Ok(Env { weights: Arc::new(weights), inputs: req })
    }

    /// Validate explicit weight tensors (as uploaded to a backend) against
    /// the spec — presence, order — and index them by name. Done once per
    /// prepared model; the result feeds [`Env::from_weights`] on every run.
    pub fn weight_env(
        artifact: &Artifact,
        weights: Vec<(String, HostTensor)>,
    ) -> Result<WeightEnv> {
        let mut map = HashMap::with_capacity(weights.len());
        let mut it = weights.into_iter();
        for spec in &artifact.inputs {
            if spec.kind == InputKind::Input {
                continue;
            }
            let (name, t) = it
                .next()
                .ok_or_else(|| err!("missing weight {}", spec.name))?;
            if name != spec.name {
                bail!("weight order mismatch: expected {}, got {name}", spec.name);
            }
            map.insert(name, t);
        }
        if let Some((name, _)) = it.next() {
            bail!("unexpected extra weight {name} for {}", artifact.name);
        }
        Ok(Arc::new(map))
    }

    /// Bind a prebuilt weight env to one request's inputs (spec order for
    /// `kind == Input`). Per-request cost: one `Arc` bump + O(#request
    /// tensors) borrowed inserts. No tensor data moves.
    pub fn from_weights(
        artifact: &'a Artifact,
        weights: &WeightEnv,
        inputs: &[&'a HostTensor],
    ) -> Result<Env<'a>> {
        let mut req = HashMap::new();
        let mut it = inputs.iter();
        for spec in &artifact.inputs {
            if spec.kind == InputKind::Input {
                let t = it
                    .next()
                    .ok_or_else(|| err!("missing request input {}", spec.name))?;
                req.insert(spec.name.as_str(), *t);
            }
        }
        if it.next().is_some() {
            bail!("too many request inputs for {}", artifact.name);
        }
        Ok(Env { weights: Arc::clone(weights), inputs: req })
    }

    /// Borrow a full spec-order input list (weights *and* request tensors,
    /// all host-side) — the one-shot `execute_all` "before" configuration of
    /// the device-resident ablation. Nothing is copied.
    pub fn from_spec_order(artifact: &'a Artifact, all: &'a [HostTensor]) -> Result<Env<'a>> {
        if all.len() != artifact.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                artifact.name,
                artifact.inputs.len(),
                all.len()
            );
        }
        let mut req = HashMap::with_capacity(all.len());
        for (spec, t) in artifact.inputs.iter().zip(all) {
            req.insert(spec.name.as_str(), t);
        }
        Ok(Env { weights: Arc::new(HashMap::new()), inputs: req })
    }

    fn get(&self, name: &str) -> Option<&HostTensor> {
        self.inputs.get(name).copied().or_else(|| self.weights.get(name))
    }

    pub fn f32(&self, name: &str) -> Result<&[f32]> {
        self.get(name)
            .and_then(HostTensor::as_f32)
            .ok_or_else(|| err!("tensor {name} missing or not f32"))
    }

    pub fn i32(&self, name: &str) -> Result<&[i32]> {
        self.get(name)
            .and_then(HostTensor::as_i32)
            .ok_or_else(|| err!("tensor {name} missing or not i32"))
    }

    pub fn i8(&self, name: &str) -> Result<&[i8]> {
        self.get(name)
            .and_then(HostTensor::as_i8)
            .ok_or_else(|| err!("tensor {name} missing or not i8"))
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        self.get(name).map(HostTensor::shape).ok_or_else(|| err!("tensor {name} missing"))
    }
}

/// Whether a reference model exists for this (model, role) pair — the
/// single source of truth for what [`eval`] below can dispatch, used by
/// `RefBackend::compile` as its "compilation" check.
pub fn supports(model: &str, role: &str) -> bool {
    matches!((model, role), ("dlrm", "sls") | ("dlrm", "dense") | ("xlmr", _) | ("cv", _))
}

/// Evaluate the reference model for an artifact over an already-built
/// environment; returns outputs in the artifact's declared order. This is
/// the single numerics path shared by `fbia validate-numerics` and the
/// [`crate::runtime::RefBackend`] interpreter. Dispatch arms must stay in
/// sync with [`supports`] directly above.
pub fn eval(manifest: &Manifest, artifact: &Artifact, env: &Env) -> Result<Vec<HostTensor>> {
    match (artifact.model.as_str(), artifact.role.as_str()) {
        ("dlrm", "sls") => dlrm_sls_ref(manifest, artifact, env),
        ("dlrm", "dense") => dlrm_dense_ref(manifest, artifact, env),
        ("xlmr", _) => xlmr_ref(manifest, artifact, env),
        ("cv", _) => cv_ref(manifest, artifact, env),
        other => bail!("no reference model for {other:?}"),
    }
}

/// Evaluate the reference model with generated weights; returns outputs in
/// the artifact's declared order.
pub fn reference_outputs(
    manifest: &Manifest,
    artifact: &Artifact,
    gen: &mut WeightGen,
    inputs: &[HostTensor],
) -> Result<Vec<HostTensor>> {
    let env = Env::build(artifact, gen, inputs)?;
    eval(manifest, artifact, &env)
}

// ---------------------------------------------------------------------------
// DLRM
// ---------------------------------------------------------------------------

fn dlrm_sls_ref(manifest: &Manifest, artifact: &Artifact, env: &Env) -> Result<Vec<HostTensor>> {
    let dim = manifest.config_usize("dlrm", "embed_dim")?;
    let batch = artifact.batch;
    let tables: Vec<usize> = artifact
        .inputs
        .iter()
        .filter(|s| s.name.starts_with("table"))
        .map(|s| crate::runtime::artifact::table_index(&s.name, "table"))
        .collect::<Result<_>>()?;
    let mut out = vec![0f32; batch * tables.len() * dim];
    for (ti, t) in tables.iter().enumerate() {
        let table = env.f32(&format!("table{t}"))?;
        let idx = env.i32(&format!("idx{t}"))?;
        let len = env.i32(&format!("len{t}"))?;
        let max_len = env.shape(&format!("idx{t}"))?[1];
        let pooled = ops::sls(table, dim, idx, len, batch, max_len)
            .with_context(|| format!("artifact {}, table{t}", artifact.name))?;
        // interleave into [batch, n_tables, dim]
        for b in 0..batch {
            let dst = (b * tables.len() + ti) * dim;
            out[dst..dst + dim].copy_from_slice(&pooled[b * dim..(b + 1) * dim]);
        }
    }
    Ok(vec![HostTensor::f32(out, &[batch, tables.len(), dim])])
}

fn mlp_ref(
    env: &Env,
    prefix: &str,
    widths: &[usize],
    mut x: Vec<f32>,
    mut d_in: usize,
    m: usize,
    quantized: bool,
    final_act: bool,
) -> Result<(Vec<f32>, usize)> {
    for (i, &h) in widths.iter().enumerate() {
        x = if quantized {
            ops::quant_fc(
                &x,
                env.i8(&format!("{prefix}_wq{i}"))?,
                env.f32(&format!("{prefix}_scale{i}"))?,
                env.f32(&format!("{prefix}_zp{i}"))?,
                env.f32(&format!("{prefix}_b{i}"))?,
                m,
                d_in,
                h,
            )
        } else {
            ops::fc(&x, env.f32(&format!("{prefix}_w{i}"))?, env.f32(&format!("{prefix}_b{i}"))?, m, d_in, h)
        };
        if i + 1 < widths.len() || final_act {
            ops::relu(&mut x);
        }
        d_in = h;
    }
    Ok((x, d_in))
}

fn dlrm_dense_ref(manifest: &Manifest, artifact: &Artifact, env: &Env) -> Result<Vec<HostTensor>> {
    let batch = artifact.batch;
    let quantized = artifact
        .inputs
        .iter()
        .any(|s| s.kind == InputKind::WeightQ);
    let dense_in = manifest.config_usize("dlrm", "dense_in")?;
    let num_tables = manifest.config_usize("dlrm", "num_tables")?;
    let embed_dim = manifest.config_usize("dlrm", "embed_dim")?;
    let bottom: Vec<usize> = read_widths(manifest, "dlrm", "bottom_mlp")?;
    let top: Vec<usize> = read_widths(manifest, "dlrm", "top_mlp")?;

    let dense = env.f32("dense")?.to_vec();
    let sparse = env.f32("sparse")?;

    let (bot, _) = mlp_ref(env, "bot", &bottom, dense, dense_in, batch, quantized, true)?;
    let inter = ops::dot_interaction(&bot, sparse, batch, embed_dim, num_tables);
    let inter_dim = embed_dim + (num_tables + 1) * num_tables / 2;
    let (mut logit, _) = mlp_ref(env, "top", &top, inter, inter_dim, batch, quantized, false)?;
    ops::sigmoid(&mut logit);
    Ok(vec![HostTensor::f32(logit, &[batch, 1])])
}

fn read_widths(manifest: &Manifest, model: &str, key: &str) -> Result<Vec<usize>> {
    manifest
        .configs
        .get(model)
        .and_then(|m| m.get(key))
        .and_then(crate::util::json::Json::as_arr)
        .map(|a| a.iter().filter_map(crate::util::json::Json::as_usize).collect())
        .ok_or_else(|| err!("manifest configs.{model}.{key} missing"))
}

// ---------------------------------------------------------------------------
// XLM-R
// ---------------------------------------------------------------------------

fn xlmr_ref(manifest: &Manifest, artifact: &Artifact, env: &Env) -> Result<Vec<HostTensor>> {
    let batch = artifact.batch;
    let seq = artifact.seq.ok_or_else(|| err!("xlmr artifact missing seq"))?;
    let layers = manifest.config_usize("xlmr", "layers")?;
    let d = manifest.config_usize("xlmr", "d_model")?;
    let heads = manifest.config_usize("xlmr", "heads")?;
    let ffn = manifest.config_usize("xlmr", "ffn")?;
    let hd = d / heads;

    let ids = env.i32("ids")?;
    let pad_len = env.i32("pad_len")?;
    let tok = env.f32("tok_emb")?;
    let pos = env.f32("pos_emb")?;

    let bs = batch * seq;
    let vocab = tok.len() / d;
    let mut x = vec![0f32; bs * d];
    for b in 0..batch {
        for s in 0..seq {
            // token ids are request data: reject out-of-vocab instead of
            // panicking on the embedding gather (same audit as ops::sls)
            let id = ids[b * seq + s];
            if id < 0 || id as usize >= vocab {
                bail!(
                    "artifact {}: token id {id} out of range for vocab {vocab} \
                     (batch row {b}, position {s})",
                    artifact.name
                );
            }
            let id = id as usize;
            let dst = (b * seq + s) * d;
            for t in 0..d {
                x[dst + t] = tok[id * d + t] + pos[s * d + t];
            }
        }
    }

    for l in 0..layers {
        let p = format!("l{l}_");
        // pre-LN attention
        let mut y = x.clone();
        ops::layernorm(&mut y, env.f32(&format!("{p}ln1_g"))?, env.f32(&format!("{p}ln1_b"))?, bs, d, 1e-5);
        let q = ops::fc(&y, env.f32(&format!("{p}wq"))?, env.f32(&format!("{p}bq"))?, bs, d, d);
        let k = ops::fc(&y, env.f32(&format!("{p}wk"))?, env.f32(&format!("{p}bk"))?, bs, d, d);
        let v = ops::fc(&y, env.f32(&format!("{p}wv"))?, env.f32(&format!("{p}bv"))?, bs, d, d);
        // [b, s, h, hd] -> per (b, h) attention
        let mut ctx = vec![0f32; bs * d];
        let mut qh = vec![0f32; seq * hd];
        let mut kh = vec![0f32; seq * hd];
        let mut vh = vec![0f32; seq * hd];
        for b in 0..batch {
            for h in 0..heads {
                for s in 0..seq {
                    let src = (b * seq + s) * d + h * hd;
                    qh[s * hd..(s + 1) * hd].copy_from_slice(&q[src..src + hd]);
                    kh[s * hd..(s + 1) * hd].copy_from_slice(&k[src..src + hd]);
                    vh[s * hd..(s + 1) * hd].copy_from_slice(&v[src..src + hd]);
                }
                let att = ops::attention(&qh, &kh, &vh, 1, seq, hd);
                for s in 0..seq {
                    let dst = (b * seq + s) * d + h * hd;
                    ctx[dst..dst + hd].copy_from_slice(&att[s * hd..(s + 1) * hd]);
                }
            }
        }
        let o = ops::fc(&ctx, env.f32(&format!("{p}wo"))?, env.f32(&format!("{p}bo"))?, bs, d, d);
        for i in 0..bs * d {
            x[i] += o[i];
        }
        // FFN
        let mut y = x.clone();
        ops::layernorm(&mut y, env.f32(&format!("{p}ln2_g"))?, env.f32(&format!("{p}ln2_b"))?, bs, d, 1e-5);
        let mut h1 = ops::fc(&y, env.f32(&format!("{p}w1"))?, env.f32(&format!("{p}b1"))?, bs, d, ffn);
        ops::gelu(&mut h1);
        let h2 = ops::fc(&h1, env.f32(&format!("{p}w2"))?, env.f32(&format!("{p}b2"))?, bs, ffn, d);
        for i in 0..bs * d {
            x[i] += h2[i];
        }
    }

    ops::layernorm(&mut x, env.f32("ln_f_g")?, env.f32("ln_f_b")?, bs, d, 1e-5);
    // masked mean pool over valid positions
    let mut pooled = vec![0f32; batch * d];
    for b in 0..batch {
        let valid = (pad_len[b].max(0) as usize).min(seq).max(0);
        let denom = valid.max(1) as f32;
        for s in 0..valid {
            for t in 0..d {
                pooled[b * d + t] += x[(b * seq + s) * d + t];
            }
        }
        for t in 0..d {
            pooled[b * d + t] /= denom;
        }
    }
    Ok(vec![
        HostTensor::f32(pooled, &[batch, d]),
        HostTensor::f32(x, &[batch, seq, d]),
    ])
}

// ---------------------------------------------------------------------------
// CV trunk
// ---------------------------------------------------------------------------

fn cv_ref(manifest: &Manifest, artifact: &Artifact, env: &Env) -> Result<Vec<HostTensor>> {
    let batch = artifact.batch;
    let image = manifest.config_usize("cv", "image")?;
    let classes = manifest.config_usize("cv", "classes")?;
    let stem_ch = manifest.config_usize("cv", "stem_ch")?;
    let groups = manifest.config_usize("cv", "groups")?;
    let stages: Vec<(usize, usize)> = manifest
        .configs
        .get("cv")
        .and_then(|m| m.get("stages"))
        .and_then(crate::util::json::Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(|s| {
                    Some((s.idx(0)?.as_usize()?, s.idx(1)?.as_usize()?))
                })
                .collect()
        })
        .ok_or_else(|| err!("manifest configs.cv.stages missing"))?;

    let img = env.f32("image")?;
    let mut x = ops::conv2d(
        img,
        env.f32("stem_w")?,
        env.f32("stem_b")?,
        batch,
        image,
        image,
        3,
        3,
        3,
        stem_ch,
        2,
        1,
    );
    ops::relu(&mut x);
    let mut h = image.div_ceil(2);
    let mut w = h;
    let mut cin = stem_ch;
    for (si, &(ch, blocks)) in stages.iter().enumerate() {
        for bi in 0..blocks {
            let p = format!("s{si}b{bi}");
            let stride = if bi == 0 && si > 0 { 2 } else { 1 };
            let mut y = ops::conv2d(
                &x,
                env.f32(&format!("{p}_pw1_w"))?,
                env.f32(&format!("{p}_pw1_b"))?,
                batch, h, w, cin, 1, 1, ch, 1, 1,
            );
            ops::relu(&mut y);
            let mut y2 = ops::conv2d(
                &y,
                env.f32(&format!("{p}_gw_w"))?,
                env.f32(&format!("{p}_gw_b"))?,
                batch, h, w, ch, 3, 3, ch, stride, groups,
            );
            ops::relu(&mut y2);
            let (oh, ow) = (h.div_ceil(stride), w.div_ceil(stride));
            let y3 = ops::conv2d(
                &y2,
                env.f32(&format!("{p}_pw2_w"))?,
                env.f32(&format!("{p}_pw2_b"))?,
                batch, oh, ow, ch, 1, 1, ch, 1, 1,
            );
            // residual
            let res = if cin != ch || stride != 1 {
                ops::conv2d(
                    &x,
                    env.f32(&format!("{p}_proj_w"))?,
                    env.f32(&format!("{p}_proj_b"))?,
                    batch, h, w, cin, 1, 1, ch, stride, 1,
                )
            } else {
                x.clone()
            };
            let mut sum: Vec<f32> = y3.iter().zip(&res).map(|(a, b)| a + b).collect();
            ops::relu(&mut sum);
            x = sum;
            h = oh;
            w = ow;
            cin = ch;
        }
    }
    let emb = ops::global_avgpool(&x, batch, h, w, cin);
    let logits = ops::fc(&emb, env.f32("head_w")?, env.f32("head_b")?, batch, cin, classes);
    Ok(vec![
        HostTensor::f32(logits, &[batch, classes]),
        HostTensor::f32(emb, &[batch, cin]),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_identical_passes() {
        let v = compare("t", &[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
        assert!(v.passed);
        assert_eq!(v.max_abs_err, 0.0);
        assert!((v.cosine - 1.0).abs() < 1e-12);
    }

    #[test]
    fn compare_divergent_fails() {
        let v = compare("t", &[1.0, 2.0, 3.0], &[3.0, -1.0, 0.5]);
        assert!(!v.passed, "{v:?}");
    }

    #[test]
    fn compare_small_noise_passes() {
        let a: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = a.iter().map(|x| x + 1e-5).collect();
        assert!(compare("t", &a, &b).passed);
    }
}
