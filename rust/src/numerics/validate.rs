//! Numerics validation (§V-C): recompute every artifact's outputs with the
//! Rust reference ops and compare against what PJRT produced.
//!
//! The reference models mirror `python/compile/models/*.py` exactly; weights
//! come from the same deterministic generator the runtime uploads, so any
//! disagreement isolates a numerics bug in the artifact/runtime path — the
//! same role the paper's FakeLowP reference implementations play against the
//! vendor kernels.
//!
//! The same evaluator is the serving hot path of `RefBackend`/`SimBackend`,
//! so it is written to be allocation-free per request in steady state:
//! every intermediate activation, scratch string (weight-name formatting)
//! and output tensor comes from the per-worker recycling
//! [`Arena`](crate::numerics::arena::Arena), and an [`EvalCtx`] carries the
//! optional pre-quantized int8 weights (built once at `prepare()`, served
//! many times — §V-B "quantize once").

use crate::compiler::quantize::{estimate_int8_error, DEFAULT_ERROR_BUDGET};
use crate::numerics::arena::Arena;
use crate::numerics::ops_ref as ops;
use crate::numerics::quant::{quantize_rowwise_int8, RowwiseInt8};
use crate::numerics::weights::WeightGen;
use crate::numerics::HostTensor;
use crate::runtime::artifact::{Artifact, InputKind, Manifest};
use crate::util::error::{bail, err, Context, Result};
use crate::util::stats::cosine_similarity;
use std::collections::HashMap;
use std::sync::Arc;

/// Comparison outcome for one artifact run.
#[derive(Debug, Clone)]
pub struct Validation {
    pub artifact: String,
    pub max_abs_err: f64,
    pub cosine: f64,
    pub passed: bool,
}

/// Tolerances: fp32 reference vs XLA CPU execution differ only by fma /
/// reassociation; int8 paths are bit-deterministic modulo float epilogue.
pub const ABS_TOL: f64 = 2e-3;
pub const COS_TOL: f64 = 0.999;

/// Compare reference vs runtime outputs.
pub fn compare(artifact: &str, reference: &[f32], measured: &[f32]) -> Validation {
    assert_eq!(reference.len(), measured.len(), "output length mismatch");
    let mut max_abs = 0f64;
    for (r, m) in reference.iter().zip(measured) {
        max_abs = max_abs.max((*r as f64 - *m as f64).abs());
    }
    let cos = cosine_similarity(reference, measured);
    Validation {
        artifact: artifact.to_string(),
        max_abs_err: max_abs,
        cosine: cos,
        passed: max_abs < ABS_TOL || cos > COS_TOL,
    }
}

/// The weight half of an evaluation environment, validated against the
/// artifact spec and indexed by name **once** (at `prepare()` time). Shared
/// by `Arc`, so binding it to a request is a refcount bump — no weight
/// tensor is ever copied on the per-request hot path (the device-resident
/// weights story of §VI-C, host-side).
pub type WeightEnv = Arc<HashMap<String, HostTensor>>;

/// Pre-quantized int8 weights keyed by the *original f32 weight name* —
/// built once at `prepare()` by [`quantize_for_serving`], consulted by the
/// evaluator on every FC/SLS so the f32 tensor never enters the hot path.
pub type QuantMap = HashMap<String, RowwiseInt8>;

/// Per-evaluation context: the worker's scratch arena plus the optional
/// int8 weight plan. `quant: None` is the pure-f32 path.
pub struct EvalCtx<'a> {
    pub quant: Option<&'a QuantMap>,
    pub arena: &'a mut Arena,
}

impl<'a> EvalCtx<'a> {
    pub fn f32_only(arena: &'a mut Arena) -> EvalCtx<'a> {
        EvalCtx { quant: None, arena }
    }
}

/// A named-tensor environment for reference evaluation: the shared weight
/// map plus per-request inputs, borrowed from the caller.
pub struct Env<'a> {
    weights: WeightEnv,
    inputs: ReqInputs<'a>,
}

/// How request tensors are held: a map for the cold validation paths, or a
/// positional spec-order slice for the serving hot path (no per-request
/// `HashMap` allocation; lookups scan the artifact's few input specs).
enum ReqInputs<'a> {
    Map(HashMap<&'a str, &'a HostTensor>),
    SpecOrder { artifact: &'a Artifact, vals: &'a [&'a HostTensor] },
}

impl<'a> Env<'a> {
    /// Build from an artifact: generated weights + provided request inputs
    /// (in spec order for `kind == Input`).
    pub fn build(
        artifact: &'a Artifact,
        gen: &mut WeightGen,
        inputs: &'a [HostTensor],
    ) -> Result<Env<'a>> {
        let mut weights = HashMap::new();
        let mut req = HashMap::new();
        let mut it = inputs.iter();
        for spec in &artifact.inputs {
            match spec.kind {
                InputKind::Input => {
                    let t = it
                        .next()
                        .ok_or_else(|| err!("missing request input {}", spec.name))?;
                    req.insert(spec.name.as_str(), t);
                }
                _ => {
                    weights.insert(spec.name.clone(), gen.generate(spec, artifact));
                }
            }
        }
        if it.next().is_some() {
            bail!("too many request inputs for {}", artifact.name);
        }
        Ok(Env { weights: Arc::new(weights), inputs: ReqInputs::Map(req) })
    }

    /// Validate explicit weight tensors (as uploaded to a backend) against
    /// the spec — presence, order — and index them by name. Done once per
    /// prepared model; the result feeds [`Env::positional`] on every run.
    pub fn weight_env(
        artifact: &Artifact,
        weights: Vec<(String, HostTensor)>,
    ) -> Result<WeightEnv> {
        let mut map = HashMap::with_capacity(weights.len());
        let mut it = weights.into_iter();
        for spec in &artifact.inputs {
            if spec.kind == InputKind::Input {
                continue;
            }
            let (name, t) = it
                .next()
                .ok_or_else(|| err!("missing weight {}", spec.name))?;
            if name != spec.name {
                bail!("weight order mismatch: expected {}, got {name}", spec.name);
            }
            map.insert(name, t);
        }
        if let Some((name, _)) = it.next() {
            bail!("unexpected extra weight {name} for {}", artifact.name);
        }
        Ok(Arc::new(map))
    }

    /// Bind a prebuilt weight env to one request's inputs (spec order for
    /// `kind == Input`). Per-request cost: one `Arc` bump + a small borrowed
    /// map. Prefer [`Env::positional`] on the hot path.
    pub fn from_weights(
        artifact: &'a Artifact,
        weights: &WeightEnv,
        inputs: &[&'a HostTensor],
    ) -> Result<Env<'a>> {
        let mut req = HashMap::new();
        let mut it = inputs.iter();
        for spec in &artifact.inputs {
            if spec.kind == InputKind::Input {
                let t = it
                    .next()
                    .ok_or_else(|| err!("missing request input {}", spec.name))?;
                req.insert(spec.name.as_str(), *t);
            }
        }
        if it.next().is_some() {
            bail!("too many request inputs for {}", artifact.name);
        }
        Ok(Env { weights: Arc::clone(weights), inputs: ReqInputs::Map(req) })
    }

    /// Bind a prebuilt weight env to positional request inputs — the
    /// zero-allocation form of [`Env::from_weights`]: no per-request map,
    /// lookups scan the spec list (a handful of entries).
    pub fn positional(
        artifact: &'a Artifact,
        weights: &WeightEnv,
        inputs: &'a [&'a HostTensor],
    ) -> Result<Env<'a>> {
        let n = artifact.inputs.iter().filter(|s| s.kind == InputKind::Input).count();
        if inputs.len() != n {
            bail!("{}: expected {n} request inputs, got {}", artifact.name, inputs.len());
        }
        Ok(Env {
            weights: Arc::clone(weights),
            inputs: ReqInputs::SpecOrder { artifact, vals: inputs },
        })
    }

    /// Borrow a full spec-order input list (weights *and* request tensors,
    /// all host-side) — the one-shot `execute_all` "before" configuration of
    /// the device-resident ablation. Nothing is copied.
    pub fn from_spec_order(artifact: &'a Artifact, all: &'a [HostTensor]) -> Result<Env<'a>> {
        if all.len() != artifact.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                artifact.name,
                artifact.inputs.len(),
                all.len()
            );
        }
        let mut req = HashMap::with_capacity(all.len());
        for (spec, t) in artifact.inputs.iter().zip(all) {
            req.insert(spec.name.as_str(), t);
        }
        Ok(Env { weights: Arc::new(HashMap::new()), inputs: ReqInputs::Map(req) })
    }

    fn get(&self, name: &str) -> Option<&HostTensor> {
        let req = match &self.inputs {
            ReqInputs::Map(m) => m.get(name).copied(),
            ReqInputs::SpecOrder { artifact, vals } => {
                let mut i = 0usize;
                let mut found = None;
                for spec in &artifact.inputs {
                    if spec.kind == InputKind::Input {
                        if spec.name == name {
                            found = vals.get(i).copied();
                            break;
                        }
                        i += 1;
                    }
                }
                found
            }
        };
        req.or_else(|| self.weights.get(name))
    }

    pub fn f32(&self, name: &str) -> Result<&[f32]> {
        self.get(name)
            .and_then(HostTensor::as_f32)
            .ok_or_else(|| err!("tensor {name} missing or not f32"))
    }

    pub fn i32(&self, name: &str) -> Result<&[i32]> {
        self.get(name)
            .and_then(HostTensor::as_i32)
            .ok_or_else(|| err!("tensor {name} missing or not i32"))
    }

    pub fn i8(&self, name: &str) -> Result<&[i8]> {
        self.get(name)
            .and_then(HostTensor::as_i8)
            .ok_or_else(|| err!("tensor {name} missing or not i8"))
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        self.get(name).map(HostTensor::shape).ok_or_else(|| err!("tensor {name} missing"))
    }
}

/// Whether a reference model exists for this (model, role) pair — the
/// single source of truth for what [`eval`] below can dispatch, used by
/// `RefBackend::compile` as its "compilation" check.
pub fn supports(model: &str, role: &str) -> bool {
    matches!((model, role), ("dlrm", "sls") | ("dlrm", "dense") | ("xlmr", _) | ("cv", _))
}

/// Evaluate the reference model for an artifact over an already-built
/// environment; returns outputs in the artifact's declared order. Pure-f32
/// convenience over [`eval_with`], using the calling thread's arena.
pub fn eval(manifest: &Manifest, artifact: &Artifact, env: &Env) -> Result<Vec<HostTensor>> {
    crate::numerics::arena::with_arena(|a| {
        eval_with(manifest, artifact, env, &mut EvalCtx::f32_only(a))
    })
}

/// The single numerics path shared by `fbia validate-numerics` and the
/// [`crate::runtime::RefBackend`] interpreter: evaluate with an explicit
/// context (scratch arena + optional int8 weight plan). Dispatch arms must
/// stay in sync with [`supports`] directly above.
pub fn eval_with(
    manifest: &Manifest,
    artifact: &Artifact,
    env: &Env,
    ctx: &mut EvalCtx,
) -> Result<Vec<HostTensor>> {
    match (artifact.model.as_str(), artifact.role.as_str()) {
        ("dlrm", "sls") => dlrm_sls_ref(manifest, artifact, env, ctx),
        ("dlrm", "dense") => dlrm_dense_ref(manifest, artifact, env, ctx),
        ("xlmr", _) => xlmr_ref(manifest, artifact, env, ctx),
        ("cv", _) => cv_ref(manifest, artifact, env, ctx),
        other => bail!("no reference model for {other:?}"),
    }
}

/// Evaluate the reference model with generated weights; returns outputs in
/// the artifact's declared order.
pub fn reference_outputs(
    manifest: &Manifest,
    artifact: &Artifact,
    gen: &mut WeightGen,
    inputs: &[HostTensor],
) -> Result<Vec<HostTensor>> {
    let env = Env::build(artifact, gen, inputs)?;
    eval(manifest, artifact, &env)
}

// ---------------------------------------------------------------------------
// int8 serving plan (quantize once at prepare, serve many)
// ---------------------------------------------------------------------------

/// One weight's int8 decision: quantize (within the per-layer error budget)
/// or keep f32. Shared by `prepare(precision=int8)` and the
/// `quantization-accuracy-budget` lint rule.
#[derive(Debug, Clone)]
pub struct Int8Decision {
    pub name: String,
    /// Reduction depth the estimated error scales with (FC k-dim, or the
    /// embedding dim for tables).
    pub k: usize,
    pub est_error: f64,
    pub quantize: bool,
    /// SLS embedding table (dequantize-on-gather) vs FC GEMM operand.
    pub table: bool,
}

/// The int8 serving plan for an artifact: FC weights quantize row-wise when
/// [`estimate_int8_error`] over their k-dim fits [`DEFAULT_ERROR_BUDGET`]
/// (mirroring `compiler::quantize`); SLS embedding tables always quantize
/// (pooling error is a few half-LSBs). Embedding gathers (`tok_emb`,
/// `pos_emb`), single-row final logit layers (the compiler's skip-last-FC
/// rule) and conv weights (4-D) stay f32.
pub fn int8_plan(art: &Artifact) -> Vec<Int8Decision> {
    let mut plan = Vec::new();
    for spec in &art.inputs {
        if spec.kind != InputKind::Weight || spec.shape.len() != 2 {
            continue;
        }
        let name = spec.name.as_str();
        if name == "tok_emb" || name == "pos_emb" {
            continue;
        }
        let (rows, k) = (spec.shape[0], spec.shape[1]);
        if name.starts_with("table") {
            plan.push(Int8Decision {
                name: name.to_string(),
                k,
                est_error: 0.5 / 127.0,
                quantize: true,
                table: true,
            });
            continue;
        }
        if rows < 2 {
            continue; // final logit layer: keep f32 (skip-last-FC policy)
        }
        let est = estimate_int8_error(k);
        plan.push(Int8Decision {
            name: name.to_string(),
            k,
            est_error: est,
            quantize: est <= DEFAULT_ERROR_BUDGET,
            table: false,
        });
    }
    plan
}

/// Pre-quantize an artifact's eligible weights row-wise for int8 serving.
/// Runs once at `prepare()`; the result is consulted by [`eval_with`] on
/// every request, so weights are never re-quantized on the hot path.
pub fn quantize_for_serving(art: &Artifact, weights: &WeightEnv) -> QuantMap {
    let mut qm = QuantMap::new();
    for dec in int8_plan(art) {
        if !dec.quantize {
            continue;
        }
        if let Some(HostTensor::F32(data, shape)) = weights.get(&dec.name) {
            qm.insert(dec.name, quantize_rowwise_int8(data, shape[0], shape[1]));
        }
    }
    qm
}

/// End-to-end error budget for an int8-served family: per-layer budgets
/// compose in quadrature across the quantized layers (independent rounding
/// errors), so the family-level gate scales with √(#quantized).
pub fn int8_family_budget(n_quantized: usize) -> f64 {
    DEFAULT_ERROR_BUDGET * (n_quantized.max(1) as f64).sqrt()
}

/// Relative L2 distance of `measured` from `reference` — the metric the
/// int8 accuracy gate compares against [`int8_family_budget`].
pub fn relative_l2(measured: &[f32], reference: &[f32]) -> f64 {
    assert_eq!(measured.len(), reference.len());
    let mut num = 0f64;
    let mut den = 0f64;
    for (m, r) in measured.iter().zip(reference) {
        num += (*m as f64 - *r as f64).powi(2);
        den += (*r as f64).powi(2);
    }
    (num / den.max(1e-12)).sqrt()
}

/// Upper bound (bytes) on the largest single f32 scratch buffer the
/// evaluator takes for this artifact — the interpreter-side analogue of the
/// static analyzer's peak-activation liveness sweep
/// ([`crate::analysis::memory::peak_activation_bytes`]). `prepare()` feeds
/// it to [`Arena::reserve`] so the ping-pong activation buffers are sized
/// before the first request. Best-effort: a short bound only means the
/// first few requests grow a buffer once.
pub fn peak_scratch_bytes(manifest: &Manifest, art: &Artifact) -> usize {
    let b = art.batch;
    let cfg = |model: &str, key: &str| manifest.config_usize(model, key).unwrap_or(0);
    let elems = match (art.model.as_str(), art.role.as_str()) {
        ("dlrm", "sls") => {
            let dim = cfg("dlrm", "embed_dim");
            let nt = art.inputs.iter().filter(|s| s.name.starts_with("table")).count();
            b * nt.max(1) * dim
        }
        ("dlrm", "dense") => {
            let nt = cfg("dlrm", "num_tables");
            let d = cfg("dlrm", "embed_dim");
            let inter = d + (nt + 1) * nt / 2;
            let mut widest = cfg("dlrm", "dense_in").max(inter);
            for key in ["bottom_mlp", "top_mlp"] {
                let mut w = Vec::new();
                if read_widths_into(manifest, "dlrm", key, &mut w).is_ok() {
                    widest = widest.max(w.into_iter().max().unwrap_or(0));
                }
            }
            b * widest
        }
        ("xlmr", _) => {
            let wide = cfg("xlmr", "d_model").max(cfg("xlmr", "ffn"));
            b * art.seq.unwrap_or(1) * wide
        }
        ("cv", _) => {
            // sweep block input resolutions the way cv_ref walks them
            let image = cfg("cv", "image");
            let (mut h, mut w) = (image.div_ceil(2), image.div_ceil(2));
            let mut peak = b * h * w * cfg("cv", "stem_ch");
            if let Some(arr) = manifest
                .configs
                .get("cv")
                .and_then(|m| m.get("stages"))
                .and_then(crate::util::json::Json::as_arr)
            {
                for (si, s) in arr.iter().enumerate() {
                    let ch = s.idx(0).and_then(|v| v.as_usize()).unwrap_or(0);
                    let blocks = s.idx(1).and_then(|v| v.as_usize()).unwrap_or(0);
                    for bi in 0..blocks {
                        // pw1 expands to `ch` at the block's input resolution
                        peak = peak.max(b * h * w * ch);
                        if bi == 0 && si > 0 {
                            h = h.div_ceil(2);
                            w = w.div_ceil(2);
                        }
                    }
                }
            }
            peak
        }
        _ => 0,
    };
    elems * std::mem::size_of::<f32>()
}

// ---------------------------------------------------------------------------
// shared helpers for the allocation-free evaluator
// ---------------------------------------------------------------------------

/// Format a weight name into a pooled scratch string (no allocation after
/// capacity convergence).
fn fmt_name<'s>(buf: &'s mut String, args: std::fmt::Arguments<'_>) -> &'s str {
    use std::fmt::Write as _;
    buf.clear();
    let _ = buf.write_fmt(args);
    buf.as_str()
}

/// Concatenate prefix + suffix into a pooled scratch string.
fn fmt2<'s>(buf: &'s mut String, prefix: &str, suffix: &str) -> &'s str {
    buf.clear();
    buf.push_str(prefix);
    buf.push_str(suffix);
    buf.as_str()
}

/// One FC through the precision dispatch: the pre-quantized int8 weight
/// when the serving plan covers `wname`, the f32 tensor otherwise. Writes
/// into `y` ([m, n]).
#[allow(clippy::too_many_arguments)]
fn fc_dispatch(
    env: &Env,
    ctx: &mut EvalCtx,
    wname: &str,
    bname: &str,
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
) -> Result<()> {
    let b = env.f32(bname)?;
    let q = ctx.quant.and_then(|qm| qm.get(wname));
    if let Some(q) = q {
        let mut xq = ctx.arena.take_i32();
        ops::quant_fc_into(x, &q.q, &q.scale, &q.zp, b, m, k, n, &mut xq, y);
        ctx.arena.give_i32(xq);
    } else {
        ops::fc_into(x, env.f32(wname)?, b, m, k, n, y);
    }
    Ok(())
}

fn read_widths_into(
    manifest: &Manifest,
    model: &str,
    key: &str,
    out: &mut Vec<usize>,
) -> Result<()> {
    let arr = manifest
        .configs
        .get(model)
        .and_then(|m| m.get(key))
        .and_then(crate::util::json::Json::as_arr)
        .ok_or_else(|| err!("manifest configs.{model}.{key} missing"))?;
    out.clear();
    out.extend(arr.iter().filter_map(crate::util::json::Json::as_usize));
    Ok(())
}

// ---------------------------------------------------------------------------
// DLRM
// ---------------------------------------------------------------------------

fn dlrm_sls_ref(
    manifest: &Manifest,
    artifact: &Artifact,
    env: &Env,
    ctx: &mut EvalCtx,
) -> Result<Vec<HostTensor>> {
    let dim = manifest.config_usize("dlrm", "embed_dim")?;
    let batch = artifact.batch;
    let n_tables = artifact.inputs.iter().filter(|s| s.name.starts_with("table")).count();
    let mut out = ctx.arena.take(batch * n_tables * dim);
    let mut pooled = ctx.arena.take(batch * dim);
    let mut nm = ctx.arena.take_str();
    let mut ti = 0usize;
    for spec in artifact.inputs.iter().filter(|s| s.name.starts_with("table")) {
        let t = crate::runtime::artifact::table_index(&spec.name, "table")?;
        let idx = env.i32(fmt_name(&mut nm, format_args!("idx{t}")))?;
        let len = env.i32(fmt_name(&mut nm, format_args!("len{t}")))?;
        let max_len = env.shape(fmt_name(&mut nm, format_args!("idx{t}")))?[1];
        let q = ctx.quant.and_then(|qm| qm.get(&spec.name));
        let r = if let Some(q) = q {
            ops::sls_q8_into(&q.q, &q.scale, &q.zp, dim, idx, len, batch, max_len, &mut pooled)
        } else {
            ops::sls_into(env.f32(&spec.name)?, dim, idx, len, batch, max_len, &mut pooled)
        };
        r.with_context(|| format!("artifact {}, table{t}", artifact.name))?;
        // interleave into [batch, n_tables, dim]
        for b in 0..batch {
            let dst = (b * n_tables + ti) * dim;
            out[dst..dst + dim].copy_from_slice(&pooled[b * dim..(b + 1) * dim]);
        }
        ti += 1;
    }
    ctx.arena.give(pooled);
    ctx.arena.give_str(nm);
    let mut outs = ctx.arena.take_outputs();
    let t = ctx.arena.tensor_f32(out, &[batch, n_tables, dim]);
    outs.push(t);
    Ok(outs)
}

#[allow(clippy::too_many_arguments)]
fn mlp_ref(
    env: &Env,
    ctx: &mut EvalCtx,
    prefix: &str,
    widths: &[usize],
    mut x: Vec<f32>,
    mut d_in: usize,
    m: usize,
    quantized: bool,
    final_act: bool,
) -> Result<(Vec<f32>, usize)> {
    let mut nm = ctx.arena.take_str();
    for (i, &h) in widths.iter().enumerate() {
        let mut y = ctx.arena.take(m * h);
        if quantized {
            // the artifact ships pre-quantized weights (InputKind::WeightQ)
            let wq = env.i8(fmt_name(&mut nm, format_args!("{prefix}_wq{i}")))?;
            let scale = env.f32(fmt_name(&mut nm, format_args!("{prefix}_scale{i}")))?;
            let zp = env.f32(fmt_name(&mut nm, format_args!("{prefix}_zp{i}")))?;
            let b = env.f32(fmt_name(&mut nm, format_args!("{prefix}_b{i}")))?;
            let mut xq = ctx.arena.take_i32();
            ops::quant_fc_into(&x, wq, scale, zp, b, m, d_in, h, &mut xq, &mut y);
            ctx.arena.give_i32(xq);
        } else {
            let q = {
                let wname = fmt_name(&mut nm, format_args!("{prefix}_w{i}"));
                ctx.quant.and_then(|qm| qm.get(wname))
            };
            let b = env.f32(fmt_name(&mut nm, format_args!("{prefix}_b{i}")))?;
            if let Some(q) = q {
                // prepare-time row-wise quantization (int8 serving path)
                let mut xq = ctx.arena.take_i32();
                ops::quant_fc_into(&x, &q.q, &q.scale, &q.zp, b, m, d_in, h, &mut xq, &mut y);
                ctx.arena.give_i32(xq);
            } else {
                let w = env.f32(fmt_name(&mut nm, format_args!("{prefix}_w{i}")))?;
                ops::fc_into(&x, w, b, m, d_in, h, &mut y);
            }
        }
        if i + 1 < widths.len() || final_act {
            ops::relu(&mut y);
        }
        ctx.arena.give(std::mem::replace(&mut x, y));
        d_in = h;
    }
    ctx.arena.give_str(nm);
    Ok((x, d_in))
}

fn dlrm_dense_ref(
    manifest: &Manifest,
    artifact: &Artifact,
    env: &Env,
    ctx: &mut EvalCtx,
) -> Result<Vec<HostTensor>> {
    let batch = artifact.batch;
    let quantized = artifact.inputs.iter().any(|s| s.kind == InputKind::WeightQ);
    let dense_in = manifest.config_usize("dlrm", "dense_in")?;
    let num_tables = manifest.config_usize("dlrm", "num_tables")?;
    let embed_dim = manifest.config_usize("dlrm", "embed_dim")?;
    let mut bottom = ctx.arena.take_usize();
    read_widths_into(manifest, "dlrm", "bottom_mlp", &mut bottom)?;
    let mut top = ctx.arena.take_usize();
    read_widths_into(manifest, "dlrm", "top_mlp", &mut top)?;

    let mut dense = ctx.arena.take(batch * dense_in);
    dense.copy_from_slice(env.f32("dense")?);
    let sparse = env.f32("sparse")?;

    let (bot, _) = mlp_ref(env, ctx, "bot", &bottom, dense, dense_in, batch, quantized, true)?;
    let inter_dim = embed_dim + (num_tables + 1) * num_tables / 2;
    let mut inter = ctx.arena.take(batch * inter_dim);
    let mut feats = ctx.arena.take((num_tables + 1) * embed_dim);
    ops::dot_interaction_into(&bot, sparse, batch, embed_dim, num_tables, &mut feats, &mut inter);
    ctx.arena.give(feats);
    ctx.arena.give(bot);
    let (mut logit, _) = mlp_ref(env, ctx, "top", &top, inter, inter_dim, batch, quantized, false)?;
    ops::sigmoid(&mut logit);
    ctx.arena.give_usize(bottom);
    ctx.arena.give_usize(top);
    let mut outs = ctx.arena.take_outputs();
    let t = ctx.arena.tensor_f32(logit, &[batch, 1]);
    outs.push(t);
    Ok(outs)
}

// ---------------------------------------------------------------------------
// XLM-R
// ---------------------------------------------------------------------------

#[allow(clippy::manual_memcpy)]
fn xlmr_ref(
    manifest: &Manifest,
    artifact: &Artifact,
    env: &Env,
    ctx: &mut EvalCtx,
) -> Result<Vec<HostTensor>> {
    use std::fmt::Write as _;
    let batch = artifact.batch;
    let seq = artifact.seq.ok_or_else(|| err!("xlmr artifact missing seq"))?;
    let layers = manifest.config_usize("xlmr", "layers")?;
    let d = manifest.config_usize("xlmr", "d_model")?;
    let heads = manifest.config_usize("xlmr", "heads")?;
    let ffn = manifest.config_usize("xlmr", "ffn")?;
    let hd = d / heads;

    let ids = env.i32("ids")?;
    let pad_len = env.i32("pad_len")?;
    let tok = env.f32("tok_emb")?;
    let pos = env.f32("pos_emb")?;

    let bs = batch * seq;
    let vocab = tok.len() / d;
    let mut x = ctx.arena.take(bs * d);
    for b in 0..batch {
        for s in 0..seq {
            // token ids are request data: reject out-of-vocab instead of
            // panicking on the embedding gather (same audit as ops::sls)
            let id = ids[b * seq + s];
            if id < 0 || id as usize >= vocab {
                bail!(
                    "artifact {}: token id {id} out of range for vocab {vocab} \
                     (batch row {b}, position {s})",
                    artifact.name
                );
            }
            let id = id as usize;
            let dst = (b * seq + s) * d;
            for t in 0..d {
                x[dst + t] = tok[id * d + t] + pos[s * d + t];
            }
        }
    }

    let mut nm = ctx.arena.take_str();
    let mut nb = ctx.arena.take_str();
    let mut p = ctx.arena.take_str();
    for l in 0..layers {
        p.clear();
        let _ = write!(p, "l{l}_");
        // pre-LN attention
        let mut y = ctx.arena.take(bs * d);
        y.copy_from_slice(&x);
        let g = env.f32(fmt2(&mut nm, &p, "ln1_g"))?;
        let gb = env.f32(fmt2(&mut nb, &p, "ln1_b"))?;
        ops::layernorm(&mut y, g, gb, bs, d, 1e-5);
        let mut q = ctx.arena.take(bs * d);
        let mut k = ctx.arena.take(bs * d);
        let mut v = ctx.arena.take(bs * d);
        fc_dispatch(env, ctx, fmt2(&mut nm, &p, "wq"), fmt2(&mut nb, &p, "bq"), &y, bs, d, d, &mut q)?;
        fc_dispatch(env, ctx, fmt2(&mut nm, &p, "wk"), fmt2(&mut nb, &p, "bk"), &y, bs, d, d, &mut k)?;
        fc_dispatch(env, ctx, fmt2(&mut nm, &p, "wv"), fmt2(&mut nb, &p, "bv"), &y, bs, d, d, &mut v)?;
        // [b, s, h, hd] -> per (b, h) attention
        let mut ctxbuf = ctx.arena.take(bs * d);
        let mut qh = ctx.arena.take(seq * hd);
        let mut kh = ctx.arena.take(seq * hd);
        let mut vh = ctx.arena.take(seq * hd);
        let mut att = ctx.arena.take(seq * hd);
        let mut scores = ctx.arena.take(seq * seq);
        for b in 0..batch {
            for h in 0..heads {
                for s in 0..seq {
                    let src = (b * seq + s) * d + h * hd;
                    qh[s * hd..(s + 1) * hd].copy_from_slice(&q[src..src + hd]);
                    kh[s * hd..(s + 1) * hd].copy_from_slice(&k[src..src + hd]);
                    vh[s * hd..(s + 1) * hd].copy_from_slice(&v[src..src + hd]);
                }
                ops::attention_into(&qh, &kh, &vh, 1, seq, hd, &mut scores, &mut att);
                for s in 0..seq {
                    let dst = (b * seq + s) * d + h * hd;
                    ctxbuf[dst..dst + hd].copy_from_slice(&att[s * hd..(s + 1) * hd]);
                }
            }
        }
        ctx.arena.give(scores);
        ctx.arena.give(att);
        ctx.arena.give(vh);
        ctx.arena.give(kh);
        ctx.arena.give(qh);
        ctx.arena.give(v);
        ctx.arena.give(k);
        ctx.arena.give(q);
        // output projection reuses y
        fc_dispatch(env, ctx, fmt2(&mut nm, &p, "wo"), fmt2(&mut nb, &p, "bo"), &ctxbuf, bs, d, d, &mut y)?;
        for i in 0..bs * d {
            x[i] += y[i];
        }
        ctx.arena.give(ctxbuf);
        // FFN (reuse y for the normed copy)
        y.copy_from_slice(&x);
        let g = env.f32(fmt2(&mut nm, &p, "ln2_g"))?;
        let gb = env.f32(fmt2(&mut nb, &p, "ln2_b"))?;
        ops::layernorm(&mut y, g, gb, bs, d, 1e-5);
        let mut h1 = ctx.arena.take(bs * ffn);
        fc_dispatch(env, ctx, fmt2(&mut nm, &p, "w1"), fmt2(&mut nb, &p, "b1"), &y, bs, d, ffn, &mut h1)?;
        ops::gelu(&mut h1);
        let mut h2 = ctx.arena.take(bs * d);
        fc_dispatch(env, ctx, fmt2(&mut nm, &p, "w2"), fmt2(&mut nb, &p, "b2"), &h1, bs, ffn, d, &mut h2)?;
        for i in 0..bs * d {
            x[i] += h2[i];
        }
        ctx.arena.give(h2);
        ctx.arena.give(h1);
        ctx.arena.give(y);
    }
    ctx.arena.give_str(p);
    ctx.arena.give_str(nb);
    ctx.arena.give_str(nm);

    ops::layernorm(&mut x, env.f32("ln_f_g")?, env.f32("ln_f_b")?, bs, d, 1e-5);
    // masked mean pool over valid positions
    let mut pooled = ctx.arena.take(batch * d);
    for b in 0..batch {
        let valid = (pad_len[b].max(0) as usize).min(seq).max(0);
        let denom = valid.max(1) as f32;
        for s in 0..valid {
            for t in 0..d {
                pooled[b * d + t] += x[(b * seq + s) * d + t];
            }
        }
        for t in 0..d {
            pooled[b * d + t] /= denom;
        }
    }
    let mut outs = ctx.arena.take_outputs();
    let tp = ctx.arena.tensor_f32(pooled, &[batch, d]);
    outs.push(tp);
    let tx = ctx.arena.tensor_f32(x, &[batch, seq, d]);
    outs.push(tx);
    Ok(outs)
}

// ---------------------------------------------------------------------------
// CV trunk
// ---------------------------------------------------------------------------

fn cv_ref(
    manifest: &Manifest,
    artifact: &Artifact,
    env: &Env,
    ctx: &mut EvalCtx,
) -> Result<Vec<HostTensor>> {
    use std::fmt::Write as _;
    let batch = artifact.batch;
    let image = manifest.config_usize("cv", "image")?;
    let classes = manifest.config_usize("cv", "classes")?;
    let stem_ch = manifest.config_usize("cv", "stem_ch")?;
    let groups = manifest.config_usize("cv", "groups")?;
    // stages fit a fixed array so request evaluation does not allocate
    let mut stages = [(0usize, 0usize); 8];
    let mut n_stages = 0usize;
    {
        let arr = manifest
            .configs
            .get("cv")
            .and_then(|m| m.get("stages"))
            .and_then(crate::util::json::Json::as_arr)
            .ok_or_else(|| err!("manifest configs.cv.stages missing"))?;
        for s in arr {
            if let (Some(ch), Some(blocks)) =
                (s.idx(0).and_then(|v| v.as_usize()), s.idx(1).and_then(|v| v.as_usize()))
            {
                if n_stages == stages.len() {
                    bail!("cv stages exceed the supported maximum of {}", stages.len());
                }
                stages[n_stages] = (ch, blocks);
                n_stages += 1;
            }
        }
    }

    let img = env.f32("image")?;
    let (mut h, mut w) = (image.div_ceil(2), image.div_ceil(2));
    let mut x = ctx.arena.take(batch * h * w * stem_ch);
    ops::conv2d_into(
        img,
        env.f32("stem_w")?,
        env.f32("stem_b")?,
        batch,
        image,
        image,
        3,
        3,
        3,
        stem_ch,
        2,
        1,
        &mut x,
    );
    ops::relu(&mut x);
    let mut cin = stem_ch;
    let mut nm = ctx.arena.take_str();
    let mut nb = ctx.arena.take_str();
    let mut pfx = ctx.arena.take_str();
    for (si, &(ch, blocks)) in stages[..n_stages].iter().enumerate() {
        for bi in 0..blocks {
            pfx.clear();
            let _ = write!(pfx, "s{si}b{bi}");
            let stride = if bi == 0 && si > 0 { 2 } else { 1 };
            let mut y = ctx.arena.take(batch * h * w * ch);
            let w1 = env.f32(fmt2(&mut nm, &pfx, "_pw1_w"))?;
            let b1 = env.f32(fmt2(&mut nb, &pfx, "_pw1_b"))?;
            ops::conv2d_into(&x, w1, b1, batch, h, w, cin, 1, 1, ch, 1, 1, &mut y);
            ops::relu(&mut y);
            let (oh, ow) = (h.div_ceil(stride), w.div_ceil(stride));
            let mut y2 = ctx.arena.take(batch * oh * ow * ch);
            let gw = env.f32(fmt2(&mut nm, &pfx, "_gw_w"))?;
            let gb = env.f32(fmt2(&mut nb, &pfx, "_gw_b"))?;
            ops::conv2d_into(&y, gw, gb, batch, h, w, ch, 3, 3, ch, stride, groups, &mut y2);
            ops::relu(&mut y2);
            ctx.arena.give(y);
            let mut y3 = ctx.arena.take(batch * oh * ow * ch);
            let pw2 = env.f32(fmt2(&mut nm, &pfx, "_pw2_w"))?;
            let pb2 = env.f32(fmt2(&mut nb, &pfx, "_pw2_b"))?;
            ops::conv2d_into(&y2, pw2, pb2, batch, oh, ow, ch, 1, 1, ch, 1, 1, &mut y3);
            ctx.arena.give(y2);
            // residual
            if cin != ch || stride != 1 {
                let mut res = ctx.arena.take(batch * oh * ow * ch);
                let pw = env.f32(fmt2(&mut nm, &pfx, "_proj_w"))?;
                let pb = env.f32(fmt2(&mut nb, &pfx, "_proj_b"))?;
                ops::conv2d_into(&x, pw, pb, batch, h, w, cin, 1, 1, ch, stride, 1, &mut res);
                for i in 0..y3.len() {
                    y3[i] += res[i];
                }
                ctx.arena.give(res);
            } else {
                for i in 0..y3.len() {
                    y3[i] += x[i];
                }
            }
            ops::relu(&mut y3);
            ctx.arena.give(std::mem::replace(&mut x, y3));
            h = oh;
            w = ow;
            cin = ch;
        }
    }
    ctx.arena.give_str(pfx);
    let mut emb = ctx.arena.take(batch * cin);
    ops::global_avgpool_into(&x, batch, h, w, cin, &mut emb);
    ctx.arena.give(x);
    let mut logits = ctx.arena.take(batch * classes);
    fc_dispatch(env, ctx, "head_w", "head_b", &emb, batch, cin, classes, &mut logits)?;
    ctx.arena.give_str(nb);
    ctx.arena.give_str(nm);
    let mut outs = ctx.arena.take_outputs();
    let tl = ctx.arena.tensor_f32(logits, &[batch, classes]);
    outs.push(tl);
    let te = ctx.arena.tensor_f32(emb, &[batch, cin]);
    outs.push(te);
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_identical_passes() {
        let v = compare("t", &[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
        assert!(v.passed);
        assert_eq!(v.max_abs_err, 0.0);
        assert!((v.cosine - 1.0).abs() < 1e-12);
    }

    #[test]
    fn compare_divergent_fails() {
        let v = compare("t", &[1.0, 2.0, 3.0], &[3.0, -1.0, 0.5]);
        assert!(!v.passed, "{v:?}");
    }

    #[test]
    fn compare_small_noise_passes() {
        let a: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = a.iter().map(|x| x + 1e-5).collect();
        assert!(compare("t", &a, &b).passed);
    }

    #[test]
    fn relative_l2_basics() {
        let r = [1.0f32, 2.0, 2.0];
        assert_eq!(relative_l2(&r, &r), 0.0);
        let off = [1.1f32, 2.2, 2.2];
        let e = relative_l2(&off, &r);
        assert!((e - 0.1).abs() < 1e-6, "{e}");
    }

    #[test]
    fn int8_plan_respects_budget_and_skip_rules() {
        let m = crate::runtime::builtin::builtin_manifest();
        // XLM-R: projections (k=256) quantize, FFN w2 (k=1024) stays f32,
        // embeddings and layernorms are never in the plan
        let art = m.get("xlmr_s64_b4").unwrap();
        let plan = int8_plan(art);
        assert!(!plan.is_empty());
        let by_name =
            |n: &str| plan.iter().find(|d| d.name == n).unwrap_or_else(|| panic!("{n} missing"));
        assert!(by_name("l0_wq").quantize);
        assert!(by_name("l0_w1").quantize);
        assert!(!by_name("l0_w2").quantize, "k=1024 exceeds the per-layer budget");
        assert!(plan.iter().all(|d| d.name != "tok_emb" && d.name != "pos_emb"));
        // DLRM sls: every table quantizes
        let sls = m.get("dlrm_sls_shard0_b16").unwrap();
        let plan = int8_plan(sls);
        assert!(plan.iter().all(|d| d.table && d.quantize));
        assert!(!plan.is_empty());
        // DLRM dense f32: the single-row logit layer is skipped entirely
        let dense = m.get("dlrm_dense_b16_fp32").unwrap();
        let plan = int8_plan(dense);
        assert!(plan.iter().all(|d| d.name != "top_w2"));
        assert!(plan.iter().any(|d| d.quantize));
    }

    #[test]
    fn family_budget_grows_with_depth() {
        assert!(int8_family_budget(1) >= DEFAULT_ERROR_BUDGET);
        assert!(int8_family_budget(20) > int8_family_budget(5));
        assert!(int8_family_budget(20) < 0.2);
    }
}
