//! Discrete-event simulation core: a seeded, bit-deterministic event heap.
//!
//! The serving tiers used to be arrival-ordered planning passes — a single
//! `for req in reqs` loop per tier that could never observe load as it
//! evolved. [`EventHeap`] replaces that with a real simulator clock:
//! arrival, completion and timer events are pushed at modeled timestamps
//! and popped in time order, so a policy can react *between* a request's
//! admission and its completion (dynamic batch growth, hedging,
//! mid-stream drain/fail).
//!
//! Determinism is the load-bearing property. There is no wall time
//! anywhere; ties between events at the same modeled instant are resolved
//! by (1) an explicit event *class* (scenario events before completions
//! before timers before arrivals, mirroring the old passes' "apply events
//! at `at_s <= t` first, prune finished work, then route" order), then
//! (2) a random draw from a seeded [`Rng`] taken at push time, then (3)
//! the push sequence number. Identical seeds and identical push sequences
//! give bit-identical pop orders on every platform — the invariant the
//! fleet/cluster "same metrics across runs and worker counts" tests pin.
//!
//! Cancellation is lazy: [`EventHeap::cancel`] marks the id and [`pop`]
//! skips it, which is O(1) and keeps the heap intact — the hedge/batching
//! policies cancel superseded completion timers constantly.
//!
//! [`pop`]: EventHeap::pop

use crate::util::rng::Rng;
use std::collections::BinaryHeap;
use std::collections::HashSet;

/// Event classes, lowest pops first at equal timestamps. The ordering
/// encodes the semantics the planning passes had implicitly: node
/// drain/fail apply before any request at the same instant is routed,
/// completions free resources before new arrivals see the queue, timers
/// (batch-close, hedge checks) observe completions but precede arrivals.
pub mod class {
    /// Scenario / operator events (drain, fail).
    pub const SCENARIO: u8 = 0;
    /// A request (or batch) finished service / was delivered.
    pub const COMPLETION: u8 = 1;
    /// Policy timers (batch-close, hedge deadline).
    pub const TIMER: u8 = 2;
    /// A request arrives (at the node, or clears an ingress link).
    pub const ARRIVAL: u8 = 3;
}

/// Opaque handle to a scheduled event, usable with [`EventHeap::cancel`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

/// A popped event.
#[derive(Debug, Clone, Copy)]
pub struct Event<K> {
    pub at_s: f64,
    pub id: EventId,
    pub kind: K,
}

struct Entry<K> {
    at_s: f64,
    class: u8,
    tie: u64,
    seq: u64,
    kind: K,
}

impl<K> PartialEq for Entry<K> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl<K> Eq for Entry<K> {}

impl<K> Ord for Entry<K> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert so the smallest key pops first
        other
            .at_s
            .total_cmp(&self.at_s)
            .then_with(|| other.class.cmp(&self.class))
            .then_with(|| other.tie.cmp(&self.tie))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<K> PartialOrd for Entry<K> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The seeded event heap. `K` is the caller's event payload.
pub struct EventHeap<K> {
    heap: BinaryHeap<Entry<K>>,
    rng: Rng,
    next_seq: u64,
    /// Seqs currently scheduled and live (not cancelled, not popped).
    queued: HashSet<u64>,
    /// Seqs cancelled but still physically in the heap (lazy removal).
    cancelled: HashSet<u64>,
    now_s: f64,
    popped: u64,
}

impl<K> EventHeap<K> {
    /// A fresh heap whose tie-breaks derive from `seed` alone.
    pub fn new(seed: u64) -> EventHeap<K> {
        EventHeap {
            heap: BinaryHeap::new(),
            rng: Rng::new(seed),
            next_seq: 0,
            queued: HashSet::new(),
            cancelled: HashSet::new(),
            now_s: 0.0,
            popped: 0,
        }
    }

    /// The modeled clock: timestamp of the last popped event (0.0 before
    /// any pop). Never decreases.
    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Events popped so far (diagnostics).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Live (scheduled, uncancelled) events.
    pub fn len(&self) -> usize {
        self.queued.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Schedule `kind` at `at_s` in the given [`class`]. Non-finite
    /// timestamps are a caller bug; clamp-to-now keeps a NaN from wedging
    /// the heap order (debug builds assert instead).
    pub fn push_class(&mut self, at_s: f64, class: u8, kind: K) -> EventId {
        debug_assert!(at_s.is_finite(), "event scheduled at non-finite time {at_s}");
        let at_s = if at_s.is_finite() { at_s } else { self.now_s };
        let seq = self.next_seq;
        self.next_seq += 1;
        // the tie draw happens at push time, so the pop order is a pure
        // function of the seed and the (deterministic) push sequence
        let tie = self.rng.next_u64();
        self.heap.push(Entry { at_s, class, tie, seq, kind });
        self.queued.insert(seq);
        EventId(seq)
    }

    /// Schedule an arrival-class event (the common case for callers that
    /// do not care about same-instant semantics).
    pub fn push(&mut self, at_s: f64, kind: K) -> EventId {
        self.push_class(at_s, class::ARRIVAL, kind)
    }

    /// Cancel a scheduled event. Returns `false` when the event already
    /// popped (or was already cancelled) — callers use that to detect
    /// lost races, e.g. "the batch I tried to grow already started".
    pub fn cancel(&mut self, id: EventId) -> bool {
        if self.queued.remove(&id.0) {
            self.cancelled.insert(id.0);
            true
        } else {
            false
        }
    }

    /// Pop the next live event and advance the clock. Cancelled events are
    /// skipped (and their tombstones dropped).
    pub fn pop(&mut self) -> Option<Event<K>> {
        while let Some(e) = self.heap.pop() {
            if self.cancelled.remove(&e.seq) {
                continue;
            }
            self.queued.remove(&e.seq);
            // the clock never runs backwards: a same-time pop keeps now
            self.now_s = self.now_s.max(e.at_s);
            self.popped += 1;
            return Some(Event { at_s: e.at_s, id: EventId(e.seq), kind: e.kind });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain a heap into (time, payload) pairs.
    fn drain(h: &mut EventHeap<u32>) -> Vec<(f64, u32)> {
        let mut out = Vec::new();
        while let Some(e) = h.pop() {
            out.push((e.at_s, e.kind));
        }
        out
    }

    #[test]
    fn pops_in_time_order_and_clock_advances() {
        let mut h = EventHeap::new(1);
        h.push(3.0, 30);
        h.push(1.0, 10);
        h.push(2.0, 20);
        assert_eq!(h.len(), 3);
        let order = drain(&mut h);
        assert_eq!(order, vec![(1.0, 10), (2.0, 20), (3.0, 30)]);
        assert_eq!(h.now_s(), 3.0);
        assert!(h.is_empty());
        assert_eq!(h.popped(), 3);
    }

    #[test]
    fn equal_timestamps_resolve_by_seeded_tie_break() {
        // same seed, same pushes -> bit-identical order, every time
        let mk = |seed| {
            let mut h = EventHeap::new(seed);
            for k in 0..16u32 {
                h.push(1.0, k);
            }
            drain(&mut h)
        };
        assert_eq!(mk(7), mk(7));
        assert_eq!(mk(13), mk(13));
        // a different seed permutes same-time events differently (16! ≫ 1
        // makes an accidental match effectively impossible)
        assert_ne!(mk(7), mk(13));
        // and the tie-break is not just insertion order for at least one
        // of the seeds
        let insertion: Vec<(f64, u32)> = (0..16).map(|k| (1.0, k)).collect();
        assert!(mk(7) != insertion || mk(13) != insertion);
    }

    #[test]
    fn class_orders_same_instant_events() {
        let mut h = EventHeap::new(3);
        // pushed in reverse class order, all at t=1.0
        h.push_class(1.0, class::ARRIVAL, 3);
        h.push_class(1.0, class::TIMER, 2);
        h.push_class(1.0, class::COMPLETION, 1);
        h.push_class(1.0, class::SCENARIO, 0);
        let kinds: Vec<u32> = std::iter::from_fn(|| h.pop()).map(|e| e.kind).collect();
        assert_eq!(kinds, vec![0, 1, 2, 3]);
    }

    #[test]
    fn cancellation_is_exact_and_idempotent() {
        let mut h = EventHeap::new(5);
        let a = h.push(1.0, 1);
        let b = h.push(2.0, 2);
        let c = h.push(3.0, 3);
        assert!(h.cancel(b));
        assert!(!h.cancel(b), "double-cancel must report failure");
        assert_eq!(h.len(), 2);
        let order = drain(&mut h);
        assert_eq!(order, vec![(1.0, 1), (3.0, 3)]);
        // popped and never-existed ids are not cancellable
        assert!(!h.cancel(a));
        assert!(!h.cancel(c));
        assert!(!h.cancel(EventId(999)));
    }

    #[test]
    fn cancel_then_reschedule_models_a_hedge() {
        // the hedge/batch-growth pattern: a completion is scheduled, a
        // policy later supersedes it with an earlier/later one
        let mut h = EventHeap::new(9);
        let slow = h.push_class(10.0, class::COMPLETION, 100);
        h.push_class(4.0, class::TIMER, 42); // hedge deadline
        let mut seen = Vec::new();
        while let Some(e) = h.pop() {
            if e.kind == 42 {
                // hedge fires: cancel the slow completion, schedule a
                // faster one
                assert!(h.cancel(slow));
                h.push_class(6.0, class::COMPLETION, 200);
            }
            seen.push((e.at_s, e.kind));
        }
        assert_eq!(seen, vec![(4.0, 42), (6.0, 200)]);
        assert_eq!(h.now_s(), 6.0);
    }

    #[test]
    fn clock_is_monotone_under_same_time_pushes() {
        let mut h = EventHeap::new(11);
        h.push(5.0, 1);
        h.pop();
        // scheduling "now" events while processing is the common pattern
        h.push_class(5.0, class::COMPLETION, 2);
        let e = h.pop().unwrap();
        assert_eq!(e.kind, 2);
        assert_eq!(h.now_s(), 5.0);
    }
}
