//! Pipelined multi-request execution simulation (Fig. 6 right).
//!
//! A request flows through a fixed stage path (upload → SLS → gather →
//! dense → download for recsys; upload → card → [host tail] → download for
//! CV/NLP). Each stage holds one FIFO resource (a card's core group, a PCIe
//! link); consecutive requests overlap across stages, which is exactly the
//! paper's steady-state pipelining of sparse and dense partitions.
//!
//! With deterministic service times and FIFO resources the tandem-queue
//! recursion start = max(prev_stage_done, resource_free) is exact — no event
//! heap needed; the serving layer (real PJRT path) handles the stochastic
//! case.

use crate::util::stats::Histogram;

/// One stage of the request path.
#[derive(Debug, Clone)]
pub struct Stage {
    pub name: String,
    /// index into the resource table (stages sharing a resource contend).
    pub resource: usize,
    /// number of interchangeable resources starting at `resource` (data-
    /// parallel replicas): the request takes the earliest-free one.
    pub pool: usize,
    /// service time per request, seconds.
    pub service_s: f64,
}

impl Stage {
    pub fn new(name: &str, resource: usize, service_s: f64) -> Stage {
        Stage { name: name.to_string(), resource, pool: 1, service_s }
    }

    pub fn pooled(name: &str, resource: usize, pool: usize, service_s: f64) -> Stage {
        Stage { name: name.to_string(), resource, pool: pool.max(1), service_s }
    }
}

/// Result of simulating a request stream through the pipeline.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    pub latency: Histogram,
    /// per-batch steady-state throughput (batches/sec).
    pub throughput: f64,
    /// per-stage busy fraction.
    pub stage_utilization: Vec<(String, f64)>,
    /// the bottleneck stage name.
    pub bottleneck: String,
    pub requests: usize,
}

/// Simulate `n` requests arriving back-to-back (closed loop, `interval=0`)
/// or at a fixed interval (open loop).
pub fn run_pipeline(stages: &[Stage], n_resources: usize, n: usize, interval_s: f64) -> PipelineResult {
    assert!(!stages.is_empty());
    let mut free = vec![0.0f64; n_resources];
    let mut busy = vec![0.0f64; n_resources];
    let mut latency = Histogram::latency();
    let mut first_start = f64::INFINITY;
    let mut last_end = 0.0f64;

    for i in 0..n {
        let arrival = i as f64 * interval_s;
        let mut t = arrival;
        for s in stages {
            // earliest-free resource in the stage's pool
            let r = (s.resource..s.resource + s.pool)
                .min_by(|&a, &b| free[a].partial_cmp(&free[b]).unwrap())
                .unwrap();
            let start = t.max(free[r]);
            let end = start + s.service_s;
            free[r] = end;
            busy[r] += s.service_s;
            t = end;
        }
        latency.add(t - arrival);
        first_start = first_start.min(arrival);
        last_end = last_end.max(t);
    }

    let span = (last_end - first_start).max(1e-12);
    let throughput = n as f64 / span;
    // per-stage utilization: attribute resource busy time to the stage(s);
    // pooled stages divide across their replicas
    let mut stage_util = Vec::new();
    for s in stages {
        stage_util.push((
            s.name.clone(),
            (s.service_s * n as f64) / (span * s.pool as f64),
        ));
    }
    let bottleneck = stages
        .iter()
        .max_by(|a, b| {
            (a.service_s / a.pool as f64)
                .partial_cmp(&(b.service_s / b.pool as f64))
                .unwrap()
        })
        .map(|s| s.name.clone())
        .unwrap_or_default();
    PipelineResult {
        latency,
        throughput,
        stage_utilization: stage_util,
        bottleneck,
        requests: n,
    }
}

/// Serial (non-pipelined) reference: the latency of one isolated request.
pub fn serial_latency(stages: &[Stage]) -> f64 {
    stages.iter().map(|s| s.service_s).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(times: &[f64]) -> Vec<Stage> {
        times
            .iter()
            .enumerate()
            .map(|(i, &t)| Stage::new(&format!("s{i}"), i, t))
            .collect()
    }

    #[test]
    fn pipelined_throughput_set_by_bottleneck() {
        let stages = mk(&[0.001, 0.004, 0.002]);
        let r = run_pipeline(&stages, 3, 200, 0.0);
        // steady state: 1/0.004 = 250/s
        assert!((r.throughput - 250.0).abs() / 250.0 < 0.05, "{}", r.throughput);
        assert_eq!(r.bottleneck, "s1");
    }

    #[test]
    fn single_request_latency_is_sum() {
        let stages = mk(&[0.001, 0.004, 0.002]);
        let r = run_pipeline(&stages, 3, 1, 0.0);
        assert!((r.latency.mean() - 0.007).abs() < 1e-6);
        assert_eq!(serial_latency(&stages), 0.007);
    }

    #[test]
    fn open_loop_below_capacity_keeps_latency_flat() {
        let stages = mk(&[0.001, 0.002]);
        let r = run_pipeline(&stages, 2, 500, 0.004); // arrival slower than svc
        assert!((r.latency.p99() - 0.003).abs() < 3e-4, "{}", r.latency.p99());
    }

    #[test]
    fn open_loop_above_capacity_queues() {
        let stages = mk(&[0.002]);
        let r = run_pipeline(&stages, 1, 300, 0.001); // 2x oversubscribed
        assert!(r.latency.p99() > 0.1, "{}", r.latency.p99()); // queue grows
    }

    #[test]
    fn shared_resource_serializes() {
        // two stages on the same resource cannot overlap
        let stages = vec![
            Stage::new("a", 0, 0.001),
            Stage::new("b", 0, 0.001),
        ];
        let r = run_pipeline(&stages, 1, 100, 0.0);
        assert!((r.throughput - 500.0).abs() / 500.0 < 0.05, "{}", r.throughput);
    }
}
