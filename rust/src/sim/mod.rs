//! Accelerator-node simulator (§VII): executes a [`CompiledModel`] on the
//! parameterized platform and reports the quantities the paper's evaluation
//! uses — latency vs the Table I budget, relative QPS (Fig. 7), per-op
//! runtime breakdown (Table II), PCIe traffic (§VI-C), core utilization.

pub mod des;
pub mod exec;
pub mod transfer;

use crate::compiler::partition::PartitionKind;
use crate::compiler::{compile, perf_model, CompiledModel};
use crate::config::Config;
use crate::graph::models::{DlrmSpec, ModelId};
use crate::graph::TensorKind;
use crate::util::error::Result;
use exec::{run_pipeline, serial_latency, PipelineResult, Stage};
use std::collections::BTreeMap;
use transfer::{TransferModel, TransferStats};

/// Simulation outcome for one model.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub model: ModelId,
    pub batch: usize,
    /// single-request (unpipelined) latency, seconds.
    pub latency_s: f64,
    /// pipelined steady-state throughput, requests/sec.
    pub qps: f64,
    /// items/sec (requests × batch).
    pub items_per_s: f64,
    pub meets_budget: bool,
    /// per-op-kind share of on-card runtime (Table II).
    pub op_breakdown: Vec<(String, f64)>,
    /// PCIe accounting per request.
    pub transfers: TransferStats,
    /// mean core utilization across partitions (weighted by makespan).
    pub core_utilization: f64,
    pub pipeline: PipelineResult,
    pub compiled: CompiledModel,
}

/// Simulate `id` under `cfg`, running `n` pipelined requests.
pub fn simulate_model(id: ModelId, cfg: &Config, n: usize) -> Result<SimReport> {
    simulate_model_batch(id, id.typical_batch(), cfg, n)
}

/// Simulate at an explicit batch size.
pub fn simulate_model_batch(id: ModelId, batch: usize, cfg: &Config, n: usize) -> Result<SimReport> {
    let g = id.build_batch(batch);
    let compiled = compile(&g, cfg)?;
    let tm = TransferModel::new(cfg.node.clone(), cfg.transfers.clone());

    let (stages, n_resources, transfers) = build_stages(id, batch, &compiled, cfg, &tm);
    let pipeline = run_pipeline(&stages, n_resources, n, 0.0);
    let latency_s = serial_latency(&stages);
    let qps = pipeline.throughput;

    // Table II: per-op share of on-card time, from the placement schedules
    let op_breakdown = op_breakdown(&compiled);

    // utilization: weighted mean over card partitions
    let (mut util_num, mut util_den) = (0.0, 0.0);
    for s in compiled.schedules.iter().flatten() {
        util_num += s.core_utilization * s.makespan_s;
        util_den += s.makespan_s;
    }
    let core_utilization = if util_den > 0.0 { util_num / util_den } else { 0.0 };

    Ok(SimReport {
        model: id,
        batch,
        latency_s,
        qps,
        items_per_s: qps * batch as f64,
        meets_budget: latency_s <= id.latency_budget_s(),
        op_breakdown,
        transfers,
        core_utilization,
        pipeline,
        compiled,
    })
}

/// Build the stage path for a model family.
fn build_stages(
    id: ModelId,
    batch: usize,
    compiled: &CompiledModel,
    cfg: &Config,
    tm: &TransferModel,
) -> (Vec<Stage>, usize, TransferStats) {
    // resource table layout (PCIe is full duplex: up and down directions
    // are independent resources):
    //   0: host x16 link, host→card direction
    //   1: host x16 link, card→host direction
    //   2: SLS core groups (all cards lockstep on one request)
    //   3: gather links into the dense card
    //   4..4+replicas: dense/full card units
    //   4+replicas: host CPU tail
    let replicas = compiled.plan.replicas.max(1);
    let host_link = 0usize;
    let host_link_down = 1usize;
    let sls_res = 2usize;
    let gather_res = 3usize;
    let card_res = 4usize;
    let host_cpu = 4 + replicas;
    let n_resources = host_cpu + 1;

    let mut stats = TransferStats::default();
    let mut stages = Vec::new();

    let is_recsys = matches!(id, ModelId::RecsysBase | ModelId::RecsysComplex);
    if is_recsys {
        let spec = match id {
            ModelId::RecsysBase => DlrmSpec::base(),
            _ => DlrmSpec::complex(),
        };
        // upload: indices to each SLS card + dense features
        let tables_per_card: Vec<usize> = compiled
            .plan
            .partitions
            .iter()
            .filter(|p| p.kind == PartitionKind::Sls)
            .map(|p| p.nodes.len())
            .collect();
        let up = tm.recsys_upload(&spec, batch, &tables_per_card);
        stages.push(Stage::new("upload", host_link, up.time_s));
        stats.add(&up);

        // SLS stage: all cards run their shard concurrently; stage time =
        // max shard makespan
        let sls_time = compiled
            .plan
            .partitions
            .iter()
            .zip(&compiled.schedules)
            .filter(|(p, _)| p.kind == PartitionKind::Sls)
            .filter_map(|(_, s)| s.as_ref())
            .map(|s| s.makespan_s)
            .fold(0.0, f64::max);
        stages.push(Stage::new("sls", sls_res, sls_time));

        // gather pooled embeddings to the dense card: transfers from every
        // other card serialize on the destination x4 link
        let mut gather_time = 0.0;
        for tr in &compiled.plan.transfers {
            let from_card = compiled.plan.partitions[tr.from].card.unwrap_or(0);
            // destination rotates per request; expected cost discounts the
            // 1-in-N case where source and destination coincide
            let t = tm.card_to_card(from_card, (from_card + 1) % cfg.node.cards, tr.bytes);
            let local_discount = 1.0 - 1.0 / cfg.node.cards as f64;
            gather_time += t.time_s * local_discount;
            let mut scaled = t;
            scaled.host_link_bytes *= local_discount;
            scaled.p2p_bytes *= local_discount;
            stats.add(&scaled);
        }
        stages.push(Stage::new("gather", gather_res, gather_time));

        // dense stage on one of the replicas
        let dense_time = compiled
            .plan
            .partitions
            .iter()
            .zip(&compiled.schedules)
            .find(|(p, _)| p.kind == PartitionKind::Dense)
            .and_then(|(_, s)| s.as_ref())
            .map(|s| s.makespan_s)
            .unwrap_or(0.0);
        stages.push(Stage::pooled("dense", card_res, replicas, dense_time));

        // download scores
        let out_bytes = batch * 4;
        let down = tm.card_to_host(0, out_bytes);
        stages.push(Stage::new("download", host_link_down, down.time_s));
        stats.add(&down);
    } else {
        // CV/NLP/video: upload input, run on one card (pool = replicas),
        // optional host tail (detection), download output
        let g = &compiled.graph;
        let in_bytes: usize = g
            .tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Input)
            .map(|t| t.bytes())
            .sum();
        let up = tm.host_to_card(0, 1, in_bytes);
        stages.push(Stage::new("upload", host_link, up.time_s));
        stats.add(&up);

        let card_time = compiled
            .plan
            .partitions
            .iter()
            .zip(&compiled.schedules)
            .find(|(p, _)| p.kind == PartitionKind::Full)
            .and_then(|(_, s)| s.as_ref())
            .map(|s| s.makespan_s)
            .unwrap_or(0.0);
        stages.push(Stage::pooled("card", card_res, replicas, card_time));

        // host-resident tail (detection proposals etc., §VI-A)
        let host_nodes: Vec<_> = compiled
            .plan
            .partitions
            .iter()
            .filter(|p| p.kind == PartitionKind::Host)
            .flat_map(|p| p.nodes.iter().copied())
            .collect();
        if !host_nodes.is_empty() {
            for tr in &compiled.plan.transfers {
                let t = tm.card_to_host(0, tr.bytes);
                stages.push(Stage::new("boundary", host_link_down, t.time_s));
                stats.add(&t);
            }
            let host_time: f64 = host_nodes
                .iter()
                .map(|&nid| perf_model::host_op_cost(g, &g.nodes[nid], &cfg.node.host))
                .sum();
            stages.push(Stage::new("host_tail", host_cpu, host_time));
        }

        let out_bytes: usize = g
            .tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Output)
            .map(|t| t.bytes())
            .sum();
        let down = tm.card_to_host(0, out_bytes);
        stages.push(Stage::new("download", host_link_down, down.time_s));
        stats.add(&down);
    }

    (stages, n_resources, stats)
}

/// Per-op-kind share of scheduled on-card time (Table II rows).
pub fn op_breakdown(compiled: &CompiledModel) -> Vec<(String, f64)> {
    let mut time: BTreeMap<&'static str, f64> = BTreeMap::new();
    for sched in compiled.schedules.iter().flatten() {
        for t in &sched.tasks {
            let kind = compiled.graph.nodes[t.node].kind.table_name();
            *time.entry(kind).or_insert(0.0) += t.end_s - t.start_s;
        }
    }
    let total: f64 = time.values().sum();
    if total <= 0.0 {
        return Vec::new();
    }
    let mut rows: Vec<(String, f64)> =
        time.into_iter().map(|(k, v)| (k.to_string(), v / total)).collect();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        Config::default()
    }

    #[test]
    fn all_models_simulate_and_meet_budgets() {
        // Fig. 7's headline: every complex model fits its latency band
        for id in ModelId::ALL {
            let r = simulate_model(id, &cfg(), 50).unwrap();
            assert!(r.latency_s > 0.0, "{:?}", id);
            assert!(r.qps > 0.0);
            assert!(
                r.meets_budget,
                "{:?}: latency {:.1} ms > budget {:.1} ms",
                id,
                r.latency_s * 1e3,
                id.latency_budget_s() * 1e3
            );
        }
    }

    #[test]
    fn recsys_faster_than_content_understanding() {
        // Fig. 7: recsys runs at much lower latency / higher QPS per batch
        let rec = simulate_model(ModelId::RecsysComplex, &cfg(), 50).unwrap();
        let reg = simulate_model(ModelId::RegNetY, &cfg(), 50).unwrap();
        assert!(rec.latency_s < reg.latency_s);
        assert!(rec.qps > reg.qps);
    }

    #[test]
    fn recsys_breakdown_dominated_by_fc_and_sls() {
        // Table II column 1: FC 30.9%, SLS 27.0% — the two largest
        let r = simulate_model(ModelId::RecsysComplex, &cfg(), 10).unwrap();
        let top2: Vec<&str> =
            r.op_breakdown.iter().take(2).map(|(k, _)| k.as_str()).collect();
        assert!(top2.contains(&"FC") || top2.contains(&"SLS"), "{:?}", r.op_breakdown);
    }

    #[test]
    fn xlmr_breakdown_dominated_by_matmul() {
        // Table II: MatMul 72.5%
        let r = simulate_model(ModelId::XlmR, &cfg(), 10).unwrap();
        assert_eq!(r.op_breakdown[0].0, "MatMul", "{:?}", r.op_breakdown);
        assert!(r.op_breakdown[0].1 > 0.4, "{:?}", r.op_breakdown);
    }

    #[test]
    fn cnn_breakdown_dominated_by_channelwise_conv() {
        let r = simulate_model(ModelId::RegNetY, &cfg(), 10).unwrap();
        assert!(r.op_breakdown[0].0.contains("Conv"), "{:?}", r.op_breakdown);
    }

    #[test]
    fn pipelining_never_below_serial_throughput() {
        // steady-state pipelined QPS is 1/max_stage >= 1/sum_stages; the gain
        // over serial depends on how balanced the stages are (the paper's
        // 1-in-3 core split exists precisely to balance them).
        let r = simulate_model(ModelId::RecsysBase, &cfg(), 100).unwrap();
        let serial_qps = 1.0 / r.latency_s;
        assert!(r.qps >= 0.999 * serial_qps, "qps {} serial {}", r.qps, serial_qps);
        // bottleneck stage is saturated in steady state
        let max_util = r
            .pipeline
            .stage_utilization
            .iter()
            .map(|(_, u)| *u)
            .fold(0.0, f64::max);
        assert!(max_util > 0.9, "{max_util}");
    }

    #[test]
    fn p2p_off_increases_host_traffic() {
        let mut c = cfg();
        c.transfers.peer_to_peer = false;
        let off = simulate_model(ModelId::RecsysBase, &c, 10).unwrap();
        let on = simulate_model(ModelId::RecsysBase, &cfg(), 10).unwrap();
        assert!(off.transfers.host_link_bytes > on.transfers.host_link_bytes * 1.5);
    }

    #[test]
    fn batch_4_improves_cv_throughput() {
        // §VI-B: batch 1→4 gives 1.6-1.8× on the CV trunk
        let b1 = simulate_model_batch(ModelId::ResNeXt101, 1, &cfg(), 50).unwrap();
        let b4 = simulate_model_batch(ModelId::ResNeXt101, 4, &cfg(), 50).unwrap();
        let speedup = b4.items_per_s / b1.items_per_s;
        assert!(speedup > 1.1, "{speedup}");
    }
}
