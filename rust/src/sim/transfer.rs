//! PCIe transfer model (§VI-C system-level optimizations).
//!
//! Computes bytes actually moved and the time they take, honoring the four
//! switchable optimizations the paper describes: partial tensors, command
//! batching, peer-to-peer transfers, and fp16 dense inputs (§VI-A). The
//! ablation bench flips each flag and reports the traffic/latency delta.

use crate::config::TransferConfig;
use crate::graph::models::DlrmSpec;
use crate::platform::topology::{host_mediated_time, Route};
use crate::platform::NodeSpec;

/// Accumulated PCIe accounting for one request.
#[derive(Debug, Clone, Copy, Default)]
pub struct TransferStats {
    /// bytes crossing the host x16 link.
    pub host_link_bytes: f64,
    /// bytes moving card↔card through the switch only.
    pub p2p_bytes: f64,
    /// number of DMA commands issued.
    pub commands: usize,
    /// total wall time of transfers (serialized worst case).
    pub time_s: f64,
}

impl TransferStats {
    pub fn total_bytes(&self) -> f64 {
        self.host_link_bytes + self.p2p_bytes
    }

    pub fn add(&mut self, other: &TransferStats) {
        self.host_link_bytes += other.host_link_bytes;
        self.p2p_bytes += other.p2p_bytes;
        self.commands += other.commands;
        self.time_s += other.time_s;
    }
}

/// Cross-request PCIe contention: a per-card link occupancy accumulator.
///
/// [`TransferModel`] prices each transfer as if the link were idle; that is
/// right for one request, but when a scheduler lands several requests on
/// one card their upload/download segments contend for the same x4 link.
/// The accumulator serializes them: a segment that wants to start at
/// `ready_s` while the link is still draining an earlier one is pushed
/// back to the link's free time. State is one `f64` per card, so the
/// resulting schedules are exactly reproducible — the fleet router's
/// latency-aware policy consults this to cost candidate placements.
#[derive(Debug, Clone)]
pub struct LinkOccupancy {
    busy_until: Vec<f64>,
}

impl LinkOccupancy {
    pub fn new(cards: usize) -> LinkOccupancy {
        LinkOccupancy { busy_until: vec![0.0; cards.max(1)] }
    }

    /// Reserve `dur_s` of link time on `card`, no earlier than `ready_s`;
    /// returns when the segment finishes. Zero-duration segments do not
    /// move the link clock but still wait for it (a request cannot start
    /// compute before the link has delivered its inputs).
    pub fn occupy(&mut self, card: usize, ready_s: f64, dur_s: f64) -> f64 {
        let i = card % self.busy_until.len();
        let start = self.busy_until[i].max(ready_s);
        self.busy_until[i] = start + dur_s;
        self.busy_until[i]
    }

    /// When `card`'s link frees up (0.0 while untouched).
    pub fn busy_until(&self, card: usize) -> f64 {
        self.busy_until[card % self.busy_until.len()]
    }
}

/// Per-node NIC occupancy: request/response bytes serialize on the node's
/// network link ([`crate::platform::NicSpec`]).
///
/// The cluster tier's requests do not materialize on a node for free — the
/// embedding index tensors, token ids and images of every request cross
/// the NIC on the way in, and the fp16 outputs cross it on the way out
/// (the paper's bandwidth-requirements discussion: enough nodes means
/// enough *network*, not just enough cards). The NIC is modeled full
/// duplex: ingress (rx) and egress (tx) serialize independently, each as a
/// single `busy_until` accumulator exactly like [`LinkOccupancy`] does for
/// a card's PCIe link, so cluster schedules stay bit-reproducible. A
/// saturated rx link delays when a request *reaches* the node's card
/// router; a saturated tx link delays when its response is delivered.
#[derive(Debug, Clone)]
pub struct NicOccupancy {
    bw_bits: f64,
    rx_until: f64,
    tx_until: f64,
    rx_busy_s: f64,
    tx_busy_s: f64,
}

impl NicOccupancy {
    /// `bw_bits` is the NIC's line rate in bits/sec (validated positive by
    /// the config layer; a non-positive rate here would produce infinite
    /// transfer times, so it is clamped to a degenerate 1 bit/s instead of
    /// panicking in the middle of a planning pass).
    pub fn new(bw_bits: f64) -> NicOccupancy {
        NicOccupancy {
            bw_bits: if bw_bits > 0.0 { bw_bits } else { 1.0 },
            rx_until: 0.0,
            tx_until: 0.0,
            rx_busy_s: 0.0,
            tx_busy_s: 0.0,
        }
    }

    /// Wire time of a payload on this NIC.
    pub fn time_s(&self, bytes: usize) -> f64 {
        bytes as f64 * 8.0 / self.bw_bits
    }

    /// Receive `bytes` no earlier than `ready_s`; returns when the last
    /// byte has arrived (the request is now visible to the node router).
    pub fn rx(&mut self, ready_s: f64, bytes: usize) -> f64 {
        let d = self.time_s(bytes);
        let start = self.rx_until.max(ready_s);
        self.rx_until = start + d;
        self.rx_busy_s += d;
        self.rx_until
    }

    /// Transmit `bytes` no earlier than `ready_s`; returns when the
    /// response is fully delivered.
    pub fn tx(&mut self, ready_s: f64, bytes: usize) -> f64 {
        let d = self.time_s(bytes);
        let start = self.tx_until.max(ready_s);
        self.tx_until = start + d;
        self.tx_busy_s += d;
        self.tx_until
    }

    /// When the ingress link frees up (0.0 while untouched) — tracing uses
    /// this to reconstruct where an rx segment started.
    pub fn rx_until(&self) -> f64 {
        self.rx_until
    }

    /// When the egress link frees up (0.0 while untouched).
    pub fn tx_until(&self) -> f64 {
        self.tx_until
    }

    /// Seconds of ingress line time consumed so far.
    pub fn rx_busy_s(&self) -> f64 {
        self.rx_busy_s
    }

    /// Seconds of egress line time consumed so far.
    pub fn tx_busy_s(&self) -> f64 {
        self.tx_busy_s
    }

    /// Forget all occupancy (node failure: the replacement starts cold).
    pub fn reset(&mut self) {
        *self = NicOccupancy::new(self.bw_bits);
    }
}

/// The transfer model: node spec + optimization flags.
#[derive(Debug, Clone)]
pub struct TransferModel {
    pub node: NodeSpec,
    pub cfg: TransferConfig,
}

impl TransferModel {
    pub fn new(node: NodeSpec, cfg: TransferConfig) -> Self {
        TransferModel { node, cfg }
    }

    /// Host → one card, `n_tensors` separate tensors of `bytes_each`.
    /// Command batching folds them into one DMA (§VI-C).
    pub fn host_to_card(&self, card: usize, n_tensors: usize, bytes_each: usize) -> TransferStats {
        let total = n_tensors * bytes_each;
        let route = Route::HostCard { card };
        let (commands, time) = if self.cfg.command_batching {
            (1, route.transfer_time(&self.node, total))
        } else {
            (
                n_tensors,
                n_tensors as f64 * route.transfer_time(&self.node, bytes_each),
            )
        };
        TransferStats {
            host_link_bytes: total as f64,
            p2p_bytes: 0.0,
            commands,
            time_s: time,
        }
    }

    /// Card → card intermediate (pooled embeddings). P2P keeps the host out
    /// (§VI-C "Removing host intermediary"); otherwise it bounces via host,
    /// crossing the host link twice.
    pub fn card_to_card(&self, from: usize, to: usize, bytes: usize) -> TransferStats {
        if from == to {
            return TransferStats::default();
        }
        if self.cfg.peer_to_peer {
            let t = Route::PeerToPeer { from, to }.transfer_time(&self.node, bytes);
            TransferStats { host_link_bytes: 0.0, p2p_bytes: bytes as f64, commands: 1, time_s: t }
        } else {
            let t = host_mediated_time(&self.node, bytes);
            TransferStats {
                host_link_bytes: 2.0 * bytes as f64,
                p2p_bytes: 0.0,
                commands: 2,
                time_s: t,
            }
        }
    }

    /// Card → host result transfer.
    pub fn card_to_host(&self, card: usize, bytes: usize) -> TransferStats {
        let t = Route::HostCard { card }.transfer_time(&self.node, bytes);
        TransferStats { host_link_bytes: bytes as f64, p2p_bytes: 0.0, commands: 1, time_s: t }
    }

    /// Recsys request upload (§VI-A + §VI-C): per-table index tensors +
    /// lengths + dense features.
    ///
    /// * partial tensors: send only `avg_lookups` of `max_lookups` index
    ///   slots per bag;
    /// * command batching: one DMA per card instead of per table;
    /// * fp16 dense inputs: halve dense feature bytes;
    /// * fused broadcast: without it, each table's input is broadcast
    ///   on-card individually, adding per-table op overhead (returned as
    ///   extra time, not bytes).
    pub fn recsys_upload(
        &self,
        spec: &DlrmSpec,
        batch: usize,
        tables_per_card: &[usize],
    ) -> TransferStats {
        let mut stats = TransferStats::default();
        let used_lookups = if self.cfg.partial_tensors {
            spec.avg_lookups.ceil() as usize
        } else {
            spec.max_lookups
        };
        let idx_bytes = batch * used_lookups * 4 + batch * 4; // indices + lengths
        for (card, &ntab) in tables_per_card.iter().enumerate() {
            if ntab == 0 {
                continue;
            }
            stats.add(&self.host_to_card(card, ntab, idx_bytes));
        }
        // dense features to the card running this request's dense replica
        let feat_elem_bytes = if self.cfg.fp16_dense_inputs { 2 } else { 4 };
        let dense_bytes = batch * spec.dense_in * feat_elem_bytes;
        stats.add(&self.host_to_card(0, 1, dense_bytes));
        // broadcast handling (§VI-A): fused => one broadcast op; unfused =>
        // one per table, each costing an op launch on the card
        let n_broadcasts = if self.cfg.fused_broadcast { 1 } else { spec.num_tables };
        stats.time_s += n_broadcasts as f64 * crate::compiler::perf_model::OP_OVERHEAD_S * 4.0;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TransferConfig;

    fn model(cfg: TransferConfig) -> TransferModel {
        TransferModel::new(NodeSpec::default(), cfg)
    }

    #[test]
    fn p2p_halves_host_link_traffic() {
        let on = model(TransferConfig::default());
        let off = model(TransferConfig { peer_to_peer: false, ..TransferConfig::default() });
        let a = on.card_to_card(0, 3, 1 << 20);
        let b = off.card_to_card(0, 3, 1 << 20);
        assert_eq!(a.host_link_bytes, 0.0);
        assert_eq!(b.host_link_bytes, 2.0 * (1 << 20) as f64);
        assert!(b.time_s > 1.9 * a.time_s);
    }

    #[test]
    fn command_batching_reduces_commands_and_time() {
        let on = model(TransferConfig::default());
        let off = model(TransferConfig { command_batching: false, ..TransferConfig::default() });
        let a = on.host_to_card(0, 40, 4096);
        let b = off.host_to_card(0, 40, 4096);
        assert_eq!(a.commands, 1);
        assert_eq!(b.commands, 40);
        assert!(b.time_s > a.time_s);
        assert_eq!(a.host_link_bytes, b.host_link_bytes); // same payload
    }

    #[test]
    fn partial_tensors_cut_index_bytes() {
        let spec = DlrmSpec::base(); // avg 20 of max 100 lookups
        let on = model(TransferConfig::default());
        let off = model(TransferConfig { partial_tensors: false, ..TransferConfig::default() });
        let tables = vec![4, 4, 4, 4, 4, 4];
        let a = on.recsys_upload(&spec, 32, &tables);
        let b = off.recsys_upload(&spec, 32, &tables);
        let ratio = b.host_link_bytes / a.host_link_bytes;
        assert!(ratio > 3.0, "ratio {ratio}"); // ~5x fewer index bytes
    }

    #[test]
    fn fp16_dense_halves_feature_bytes() {
        let mut spec = DlrmSpec::base();
        spec.num_tables = 0; // isolate the dense features
        let on = model(TransferConfig::default());
        let off = model(TransferConfig { fp16_dense_inputs: false, ..TransferConfig::default() });
        let a = on.recsys_upload(&spec, 32, &[]);
        let b = off.recsys_upload(&spec, 32, &[]);
        assert!((b.host_link_bytes / a.host_link_bytes - 2.0).abs() < 1e-9);
    }

    #[test]
    fn same_card_transfer_is_free() {
        let m = model(TransferConfig::default());
        let s = m.card_to_card(2, 2, 1 << 20);
        assert_eq!(s.total_bytes(), 0.0);
        assert_eq!(s.time_s, 0.0);
    }

    #[test]
    fn link_occupancy_serializes_same_card_segments() {
        let mut l = LinkOccupancy::new(6);
        // two requests land on card 2 at the same instant: the second's
        // transfer waits for the first
        let a = l.occupy(2, 0.0, 1e-3);
        let b = l.occupy(2, 0.0, 1e-3);
        assert!((a - 1e-3).abs() < 1e-12);
        assert!((b - 2e-3).abs() < 1e-12, "second segment must queue: {b}");
        // a different card's link is independent
        let c = l.occupy(3, 0.0, 1e-3);
        assert!((c - 1e-3).abs() < 1e-12);
        // an idle gap is not billed
        let d = l.occupy(3, 10.0, 1e-3);
        assert!((d - 10.001).abs() < 1e-9);
        assert_eq!(l.busy_until(0), 0.0);
    }

    #[test]
    fn link_occupancy_zero_duration_waits_but_does_not_occupy() {
        let mut l = LinkOccupancy::new(2);
        l.occupy(0, 0.0, 5e-3);
        // a zero-byte segment still cannot finish before the link frees
        let t = l.occupy(0, 1e-3, 0.0);
        assert!((t - 5e-3).abs() < 1e-12);
        assert!((l.busy_until(0) - 5e-3).abs() < 1e-12);
    }

    #[test]
    fn nic_occupancy_serializes_and_is_full_duplex() {
        // 1 MB at 8 Mbit/s = 1 second on the wire
        let mut n = NicOccupancy::new(8e6);
        let a = n.rx(0.0, 1_000_000);
        assert!((a - 1.0).abs() < 1e-12);
        // a second request arriving at the same instant queues behind it
        let b = n.rx(0.0, 1_000_000);
        assert!((b - 2.0).abs() < 1e-12, "rx must serialize: {b}");
        // egress is independent of ingress (full duplex)
        let c = n.tx(0.0, 1_000_000);
        assert!((c - 1.0).abs() < 1e-12, "tx must not wait for rx: {c}");
        // an idle gap is not billed
        let d = n.rx(10.0, 500_000);
        assert!((d - 10.5).abs() < 1e-12);
        assert!((n.rx_busy_s() - 2.5).abs() < 1e-12);
        assert!((n.tx_busy_s() - 1.0).abs() < 1e-12);
        n.reset();
        assert_eq!(n.rx_busy_s(), 0.0);
        assert!((n.rx(0.0, 1_000_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn halved_nic_bandwidth_doubles_wire_time() {
        let full = NicOccupancy::new(50e9);
        let half = NicOccupancy::new(25e9);
        let bytes = 1 << 20;
        assert!((half.time_s(bytes) / full.time_s(bytes) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn unfused_broadcast_costs_time_not_bytes() {
        let spec = DlrmSpec::base();
        let on = model(TransferConfig::default());
        let off = model(TransferConfig { fused_broadcast: false, ..TransferConfig::default() });
        let tables = vec![4; 6];
        let a = on.recsys_upload(&spec, 32, &tables);
        let b = off.recsys_upload(&spec, 32, &tables);
        assert_eq!(a.host_link_bytes, b.host_link_bytes);
        assert!(b.time_s > a.time_s);
    }
}
