//! CNN graph generators: ResNeXt101-32x4, RegNetY, FBNetV3 detection, and
//! the ResNeXt3D video trunk (§II-B, §II-D; Table I rows 3–6).
//!
//! All four share the bottleneck pattern the paper highlights: pointwise
//! (1×1) convs + grouped/channelwise 3×3 convs, residual adds, pooling. The
//! detection model adds the host-resident region-proposal ops (ROIAlign,
//! NMS) that §VI-A keeps on the CPU.

use crate::graph::models::{add_conv, add_fc, add_relu};
use crate::graph::ops::OpKind;
use crate::graph::{DType, Graph, Shape, TensorId, TensorKind};

/// Generic staged-CNN description used by all four builders.
#[derive(Debug, Clone)]
pub struct CnnSpec {
    pub name: &'static str,
    pub image: usize,
    pub stem_ch: usize,
    /// (bottleneck_width, out_channels, blocks, groups)
    pub stages: Vec<(usize, usize, usize, usize)>,
    pub classes: usize,
    pub quantized: bool,
    /// Squeeze-and-Excitation blocks (the Y in RegNetY): a global average
    /// pool + two tiny FCs + channel-wise Mul per bottleneck. These are why
    /// Table II shows RegNetY spending 6% in AdaptiveAvgPool and 4.4% in
    /// Mul — and why the §VI-B avgpool optimization mattered so much.
    pub se_blocks: bool,
}

#[allow(clippy::too_many_arguments)]
fn bottleneck(
    g: &mut Graph,
    name: &str,
    x: TensorId,
    width: usize,
    cout: usize,
    stride: usize,
    groups: usize,
    quantized: bool,
    se: bool,
) -> TensorId {
    let a = add_conv(g, &format!("{name}.pw1"), x, width, 1, 1, 1, quantized, false);
    let a = add_relu(g, &format!("{name}.relu1"), a);
    let mut b = add_conv(g, &format!("{name}.gw"), a, width, 3, stride, groups, quantized, false);
    b = add_relu(g, &format!("{name}.relu2"), b);
    if se {
        // squeeze: global average pool over the spatial dims
        let bs = g.tensor(b).shape.clone();
        let (n, ch) = (bs.dim(0), bs.dim(3));
        let squeezed = g.add_tensor(
            &format!("{name}.se.pool"),
            Shape::new(&[n, ch]),
            DType::F32,
            TensorKind::Activation,
        );
        g.add_node(
            &format!("{name}.se.avgpool"),
            OpKind::AdaptiveAvgPool { optimized: true },
            vec![b],
            vec![squeezed],
        );
        // excite: bottleneck FC pair
        let r = (ch / 4).max(8);
        let f1 = add_fc(g, &format!("{name}.se.fc1"), squeezed, r, false);
        let f1 = add_relu(g, &format!("{name}.se.relu"), f1);
        let f2 = add_fc(g, &format!("{name}.se.fc2"), f1, ch, false);
        let gate = g.add_tensor(
            &format!("{name}.se.gate"),
            Shape::new(&[n, ch]),
            DType::F32,
            TensorKind::Activation,
        );
        g.add_node(&format!("{name}.se.sigmoid"), OpKind::Sigmoid, vec![f2], vec![gate]);
        // channel-wise scale (the Table II "Mul" rows)
        let scaled = g.add_tensor(
            &format!("{name}.se.mul"),
            bs.clone(),
            DType::F32,
            TensorKind::Activation,
        );
        g.add_node(&format!("{name}.se.scale"), OpKind::Mul, vec![b, gate], vec![scaled]);
        b = scaled;
    }
    // final pointwise fused with the residual add (vendor "Fused Conv_Add")
    let c = add_conv(g, &format!("{name}.pw2"), b, cout, 1, 1, 1, quantized, true);
    add_relu(g, &format!("{name}.relu3"), c)
}

/// Build a staged CNN classifier trunk.
pub fn staged_cnn(spec: &CnnSpec, batch: usize) -> Graph {
    let mut g = Graph::new(spec.name);
    let img = g.add_tensor(
        "image",
        Shape::new(&[batch, spec.image, spec.image, 3]),
        DType::F32,
        TensorKind::Input,
    );
    // quantize input once (first conv stays higher precision per §V-B; model
    // it as the stem running non-quantized)
    let mut x = add_conv(&mut g, "stem", img, spec.stem_ch, 7, 2, 1, false, false);
    x = add_relu(&mut g, "stem.relu", x);
    let mp = {
        let s = g.tensor(x).shape.clone();
        let y = g.add_tensor(
            "stem.pool",
            Shape::new(&[batch, s.dim(1) / 2, s.dim(2) / 2, spec.stem_ch]),
            DType::F32,
            TensorKind::Activation,
        );
        g.add_node("stem.maxpool", OpKind::MaxPool { kh: 3, kw: 3 }, vec![x], vec![y]);
        y
    };
    x = mp;
    for (si, &(width, cout, blocks, groups)) in spec.stages.iter().enumerate() {
        for bi in 0..blocks {
            let stride = if bi == 0 && si > 0 { 2 } else { 1 };
            x = bottleneck(
                &mut g,
                &format!("s{si}b{bi}"),
                x,
                width,
                cout,
                stride,
                groups,
                spec.quantized,
                spec.se_blocks,
            );
        }
    }
    // global average pool: the op the paper had to optimize for all pooling
    // sizes (§VI-B "Average pool optimization")
    let s = g.tensor(x).shape.clone();
    let emb = g.add_tensor(
        "embedding",
        Shape::new(&[batch, s.dim(3)]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node(
        "global_avgpool",
        OpKind::AdaptiveAvgPool { optimized: true },
        vec![x],
        vec![emb],
    );
    let logits = add_fc(&mut g, "head", emb, spec.classes, false);
    let out = g.add_tensor(
        "logits",
        Shape::new(&[batch, spec.classes]),
        DType::F32,
        TensorKind::Output,
    );
    g.add_node("softmax", OpKind::Softmax, vec![logits], vec![out]);
    g
}

/// ResNeXt101-32x4d (Table I: 44 MParams, 15.6 GFLOPs @224).
pub fn resnext101(batch: usize) -> Graph {
    staged_cnn(
        &CnnSpec {
            name: "resnext101",
            image: 224,
            stem_ch: 64,
            // ResNeXt101-32x4d: widths 128..1024, groups 32, out 256..2048
            stages: vec![
                (128, 256, 3, 32),
                (256, 512, 4, 32),
                (512, 1024, 23, 32),
                (1024, 2048, 3, 32),
            ],
            classes: 1000,
            quantized: true,
            se_blocks: false,
        },
        batch,
    )
}

/// RegNetY-class large model (Table I: ~700 MParams, 256 GFLOPs @224).
/// Calibrated RegNet-style widths/depths; grouped convs with wide groups.
pub fn regnety(batch: usize) -> Graph {
    staged_cnn(
        &CnnSpec {
            name: "regnety",
            image: 224,
            stem_ch: 32,
            stages: vec![
                (528, 528, 2, 4),
                (1056, 1056, 6, 8),
                (2904, 2904, 14, 16),
                (7392, 7392, 3, 28),
            ],
            classes: 1000,
            quantized: true,
            se_blocks: true,
        },
        batch,
    )
}

/// FBNetV3-based detection model (Table I: 28.6 MParams, 72 GFLOPs, AI 1946
/// from the large 640² input). Backbone + region proposals + ROI heads; the
/// proposal ops run host-side in the paper (§VI-A).
pub fn fbnetv3(batch: usize) -> Graph {
    let mut g = staged_cnn(
        &CnnSpec {
            name: "fbnetv3_det",
            image: 640,
            stem_ch: 24,
            stages: vec![
                (96, 96, 4, 96),     // depthwise-style: groups == width
                (192, 192, 6, 192),
                (384, 384, 8, 384),
                (736, 736, 6, 736),
            ],
            classes: 80,
            quantized: true,
            se_blocks: false,
        },
        batch,
    );
    // detection head: proposals (host) + ROIAlign (host) + two-FC box head
    // (the Faster-RCNN-style head that carries most of the model's params)
    let feat = g
        .tensors
        .iter()
        .find(|t| t.name == "embedding")
        .map(|t| t.id)
        .expect("embedding tensor");
    let rois = g.add_tensor(
        "rois",
        Shape::new(&[batch, 100, 4]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node("nms_proposals", OpKind::NonMaxSuppression, vec![feat], vec![rois]);
    let roi_feats = g.add_tensor(
        "roi_feats",
        Shape::new(&[batch * 100, 7 * 7 * 736]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node("roi_align", OpKind::RoiAlign, vec![rois], vec![roi_feats]);
    let h1 = add_fc(&mut g, "box_fc1", roi_feats, 512, true);
    let h1 = add_relu(&mut g, "box_relu1", h1);
    let h2 = add_fc(&mut g, "box_fc2", h1, 512, true);
    let h2 = add_relu(&mut g, "box_relu2", h2);
    let cls = add_fc(&mut g, "box_head", h2, 80, true);
    let boxes = g.add_tensor(
        "detections",
        Shape::new(&[batch, 100, 80]),
        DType::F32,
        TensorKind::Output,
    );
    g.add_node("box_softmax", OpKind::Softmax, vec![cls], vec![boxes]);
    g
}

/// ResNeXt3D video trunk (Table I: 58 MParams, 3.4 GFLOPs per 4-frame clip).
/// Channel-separated 3D convs: 1×1×1 cross-channel + 3×3×3 depthwise (§II-D).
pub fn resnext3d(batch: usize) -> Graph {
    let mut g = Graph::new("resnext3d");
    let frames = 4usize;
    let res = 112usize;
    let clip = g.add_tensor(
        "clip",
        Shape::new(&[batch, frames, res, res, 3]),
        DType::F32,
        TensorKind::Input,
    );
    // stem 3D conv
    let stem_w = g.add_tensor("stem.w", Shape::new(&[3, 7, 7, 3, 64]), DType::F16, TensorKind::Weight);
    let mut cur = g.add_tensor(
        "stem.y",
        Shape::new(&[batch, frames, res / 2, res / 2, 64]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node(
        "stem",
        OpKind::Conv3D { groups: 1, kt: 3, kh: 7, kw: 7 },
        vec![clip, stem_w],
        vec![cur],
    );

    // aggressive spatial reduction (§II-D: "reduced spatial resolution"):
    // params stay high (58 M class) while per-clip FLOPs stay ~3-4 G.
    let stages: Vec<(usize, usize, usize)> =
        vec![(512, 3, 14), (1024, 4, 7), (2048, 6, 4), (2048, 3, 2)];
    for (si, &(ch, blocks, spatial)) in stages.iter().enumerate() {
        for bi in 0..blocks {
            let name = format!("v{si}b{bi}");
            let cin = g.tensor(cur).shape.dim(4);
            // 1x1x1 cross-channel
            let w1 = g.add_tensor(
                &format!("{name}.pw.w"),
                Shape::new(&[1, 1, 1, cin, ch / 2]),
                DType::F16,
                TensorKind::Weight,
            );
            let y1 = g.add_tensor(
                &format!("{name}.pw.y"),
                Shape::new(&[batch, frames, spatial, spatial, ch / 2]),
                DType::F32,
                TensorKind::Activation,
            );
            g.add_node(
                &format!("{name}.pw"),
                OpKind::Conv3D { groups: 1, kt: 1, kh: 1, kw: 1 },
                vec![cur, w1],
                vec![y1],
            );
            // 3x3x3 depthwise
            let w2 = g.add_tensor(
                &format!("{name}.dw.w"),
                Shape::new(&[3, 3, 3, 1, ch / 2]),
                DType::F16,
                TensorKind::Weight,
            );
            let y2 = g.add_tensor(
                &format!("{name}.dw.y"),
                Shape::new(&[batch, frames, spatial, spatial, ch / 2]),
                DType::F32,
                TensorKind::Activation,
            );
            g.add_node(
                &format!("{name}.dw"),
                OpKind::Conv3D { groups: ch / 2, kt: 3, kh: 3, kw: 3 },
                vec![y1, w2],
                vec![y2],
            );
            // 1x1x1 expand
            let w3 = g.add_tensor(
                &format!("{name}.pw2.w"),
                Shape::new(&[1, 1, 1, ch / 2, ch]),
                DType::F16,
                TensorKind::Weight,
            );
            let y3 = g.add_tensor(
                &format!("{name}.pw2.y"),
                Shape::new(&[batch, frames, spatial, spatial, ch]),
                DType::F32,
                TensorKind::Activation,
            );
            g.add_node(
                &format!("{name}.pw2"),
                OpKind::Conv3D { groups: 1, kt: 1, kh: 1, kw: 1 },
                vec![y2, w3],
                vec![y3],
            );
            // bandwidth-bound tail: batchnorm + residual add + pool every
            // block (the fusion-pressure ops of §II-D)
            let bn = g.add_tensor(
                &format!("{name}.bn.y"),
                Shape::new(&[batch, frames, spatial, spatial, ch]),
                DType::F32,
                TensorKind::Activation,
            );
            g.add_node(&format!("{name}.bn"), OpKind::BatchNorm, vec![y3], vec![bn]);
            if g.tensor(cur).shape == g.tensor(bn).shape {
                let add = g.add_tensor(
                    &format!("{name}.add.y"),
                    Shape::new(&[batch, frames, spatial, spatial, ch]),
                    DType::F32,
                    TensorKind::Activation,
                );
                g.add_node(&format!("{name}.add"), OpKind::Add, vec![cur, bn], vec![add]);
                cur = add;
            } else {
                cur = bn;
            }
        }
        // spatial maxpool between stages (bandwidth-bound, §II-D)
        if si + 1 < stages.len() {
            let next_spatial = stages[si + 1].2;
            let ch = g.tensor(cur).shape.dim(4);
            let y = g.add_tensor(
                &format!("pool{si}.y"),
                Shape::new(&[batch, frames, next_spatial, next_spatial, ch]),
                DType::F32,
                TensorKind::Activation,
            );
            g.add_node(&format!("pool{si}"), OpKind::MaxPool { kh: 2, kw: 2 }, vec![cur], vec![y]);
            cur = y;
        }
    }

    let ch = g.tensor(cur).shape.dim(4);
    let emb = g.add_tensor("embedding", Shape::new(&[batch, ch]), DType::F32, TensorKind::Activation);
    g.add_node("global_avgpool", OpKind::AdaptiveAvgPool { optimized: true }, vec![cur], vec![emb]);
    let logits = add_fc(&mut g, "head", emb, 400, false);
    let out = g.add_tensor("scores", Shape::new(&[batch, 400]), DType::F32, TensorKind::Output);
    g.add_node("softmax", OpKind::Softmax, vec![logits], vec![out]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnext101_table1_scale() {
        let g = resnext101(1);
        g.validate().unwrap();
        let mp = g.param_count() as f64 / 1e6;
        assert!(mp > 30.0 && mp < 60.0, "params {mp} M");
        let gf = g.total_flops() / 1e9;
        assert!(gf > 8.0 && gf < 25.0, "flops {gf} G");
    }

    #[test]
    fn regnety_table1_scale() {
        let g = regnety(1);
        g.validate().unwrap();
        let mp = g.param_count() as f64 / 1e6;
        assert!(mp > 400.0 && mp < 1000.0, "params {mp} M");
        let gf = g.total_flops() / 1e9;
        assert!(gf > 120.0 && gf < 400.0, "flops {gf} G");
    }

    #[test]
    fn regnety_much_bigger_than_resnext() {
        // paper: RegNetY ~15x ResNeXt101 in params and FLOPs
        let a = resnext101(1);
        let b = regnety(1);
        let pr = b.param_count() as f64 / a.param_count() as f64;
        let fr = b.total_flops() / a.total_flops();
        assert!(pr > 8.0, "param ratio {pr}");
        assert!(fr > 8.0, "flop ratio {fr}");
    }

    #[test]
    fn fbnetv3_has_host_ops() {
        let g = fbnetv3(1);
        g.validate().unwrap();
        assert!(g.nodes.iter().any(|n| n.kind.host_only()));
        let mp = g.param_count() as f64 / 1e6;
        assert!(mp > 10.0 && mp < 80.0, "params {mp} M");
    }

    #[test]
    fn resnext3d_table1_scale() {
        let g = resnext3d(1);
        g.validate().unwrap();
        let mp = g.param_count() as f64 / 1e6;
        assert!(mp > 20.0 && mp < 100.0, "params {mp} M");
        let gf = g.total_flops() / 1e9;
        assert!(gf > 1.0 && gf < 15.0, "flops {gf} G");
    }

    #[test]
    fn grouped_convs_dominate_cnn_flops() {
        let g = resnext101(1);
        let hist = g.op_histogram();
        let total: f64 = hist.values().sum();
        let grouped = hist.get("ChannelwiseQuantizedConv").copied().unwrap_or(0.0)
            + hist.get("QuantizedConv").copied().unwrap_or(0.0)
            + hist.get("Fused Conv_Add").copied().unwrap_or(0.0);
        assert!(grouped / total > 0.7, "conv share {}", grouped / total);
    }

    #[test]
    fn cnn_arithmetic_intensity_is_high() {
        // Table I: CV models have AI in the hundreds
        let g = resnext101(1);
        let ai = g.arithmetic_intensity();
        assert!(ai > 100.0, "{ai}");
    }
}
