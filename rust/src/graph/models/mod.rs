//! Graph generators for the six Table I models.
//!
//! These are *paper-scale shape descriptors* used by the compiler and the
//! simulator (the runnable PJRT artifacts are the scaled-down JAX models in
//! `python/compile/models`). Each builder is calibrated against Table I:
//! parameter count, per-batch GFLOPs, and arithmetic intensity.

mod cnn;
mod dlrm;
mod xlmr;

pub use cnn::{fbnetv3, regnety, resnext101, resnext3d, staged_cnn, CnnSpec};
pub use dlrm::{dlrm, DlrmSpec};
pub use xlmr::{xlmr, XlmrSpec};

use crate::graph::{DType, Graph, Shape, TensorId, TensorKind};
use crate::graph::ops::OpKind;

/// The model zoo of Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelId {
    /// "Less complex" recommendation model.
    RecsysBase,
    /// "More complex" (the 5× GFLOPs model served in §VII).
    RecsysComplex,
    ResNeXt101,
    RegNetY,
    FbNetV3,
    ResNeXt3D,
    XlmR,
}

impl ModelId {
    pub const ALL: [ModelId; 7] = [
        ModelId::RecsysBase,
        ModelId::RecsysComplex,
        ModelId::ResNeXt101,
        ModelId::RegNetY,
        ModelId::FbNetV3,
        ModelId::ResNeXt3D,
        ModelId::XlmR,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ModelId::RecsysBase => "Recsys (less complex)",
            ModelId::RecsysComplex => "Recsys (more complex)",
            ModelId::ResNeXt101 => "ResNeXt101-32x4-48",
            ModelId::RegNetY => "RegNetY",
            ModelId::FbNetV3 => "FBNetV3 based",
            ModelId::ResNeXt3D => "ResNeXt3D based",
            ModelId::XlmR => "XLM-R",
        }
    }

    /// Latency constraint from Table I, seconds.
    pub fn latency_budget_s(&self) -> f64 {
        match self {
            ModelId::RecsysBase | ModelId::RecsysComplex => 0.100,
            ModelId::ResNeXt101 | ModelId::RegNetY => 1.0,
            ModelId::FbNetV3 => 0.300,
            ModelId::ResNeXt3D => 0.350,
            ModelId::XlmR => 0.200,
        }
    }

    /// Typical batch size from Table I.
    pub fn typical_batch(&self) -> usize {
        match self {
            ModelId::RecsysBase | ModelId::RecsysComplex => 32,
            ModelId::XlmR => 1,
            _ => 1,
        }
    }

    /// Build the graph at the model's typical batch size.
    pub fn build(&self) -> Graph {
        self.build_batch(self.typical_batch())
    }

    /// Build the graph at an explicit batch size.
    pub fn build_batch(&self, batch: usize) -> Graph {
        match self {
            ModelId::RecsysBase => dlrm(&DlrmSpec::base(), batch),
            ModelId::RecsysComplex => dlrm(&DlrmSpec::complex(), batch),
            ModelId::ResNeXt101 => resnext101(batch),
            ModelId::RegNetY => regnety(batch),
            ModelId::FbNetV3 => fbnetv3(batch),
            ModelId::ResNeXt3D => resnext3d(batch),
            ModelId::XlmR => xlmr(&XlmrSpec::paper(), batch, 32),
        }
    }
}

// ---------------------------------------------------------------------------
// Shared builder helpers
// ---------------------------------------------------------------------------

/// Add an FC layer (optionally int8) and return the output tensor.
pub(crate) fn add_fc(
    g: &mut Graph,
    name: &str,
    x: TensorId,
    out_features: usize,
    quantized: bool,
) -> TensorId {
    let xs = g.tensor(x).shape.clone();
    let (m, k) = (xs.dim(0), xs.dim(1));
    let wdt = if quantized { DType::I8 } else { DType::F16 };
    let w = g.add_tensor(&format!("{name}.w"), Shape::new(&[out_features, k]), wdt, TensorKind::Weight);
    let b = g.add_tensor(&format!("{name}.b"), Shape::new(&[out_features]), DType::F32, TensorKind::Weight);
    let y = g.add_tensor(&format!("{name}.y"), Shape::new(&[m, out_features]), DType::F32, TensorKind::Activation);
    let kind = if quantized { OpKind::QuantizedFc } else { OpKind::Fc };
    g.add_node(name, kind, vec![x, w, b], vec![y]);
    y
}

/// Add a ReLU.
pub(crate) fn add_relu(g: &mut Graph, name: &str, x: TensorId) -> TensorId {
    let s = g.tensor(x).shape.clone();
    let y = g.add_tensor(&format!("{name}.y"), s, DType::F32, TensorKind::Activation);
    g.add_node(name, OpKind::Relu, vec![x], vec![y]);
    y
}

/// Add a 2D conv (NHWC); returns output tensor.
#[allow(clippy::too_many_arguments)]
pub(crate) fn add_conv(
    g: &mut Graph,
    name: &str,
    x: TensorId,
    cout: usize,
    k: usize,
    stride: usize,
    groups: usize,
    quantized: bool,
    fused_add: bool,
) -> TensorId {
    let xs = g.tensor(x).shape.clone();
    let (n, h, w, cin) = (xs.dim(0), xs.dim(1), xs.dim(2), xs.dim(3));
    let wdt = if quantized { DType::I8 } else { DType::F16 };
    let wt = g.add_tensor(
        &format!("{name}.w"),
        Shape::new(&[k, k, cin / groups, cout]),
        wdt,
        TensorKind::Weight,
    );
    let (oh, ow) = (h.div_ceil(stride), w.div_ceil(stride));
    let y = g.add_tensor(
        &format!("{name}.y"),
        Shape::new(&[n, oh, ow, cout]),
        DType::F32,
        TensorKind::Activation,
    );
    let kind = if fused_add {
        OpKind::ConvAddFused { groups, stride, kh: k, kw: k, quantized }
    } else {
        OpKind::Conv { groups, stride, kh: k, kw: k, quantized }
    };
    g.add_node(name, kind, vec![x, wt], vec![y]);
    y
}
