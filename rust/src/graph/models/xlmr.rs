//! XLM-R graph generator at paper scale (§II-C, Table I last row): 24
//! transformer layers, 558 M parameters, ~20 GFLOPs at 32 tokens. Runtime is
//! MatMul-dominated (72.5% in Table II).

use crate::graph::ops::OpKind;
use crate::graph::{DType, Graph, Shape, TensorId, TensorKind};

#[derive(Debug, Clone)]
pub struct XlmrSpec {
    pub layers: usize,
    pub d_model: usize,
    pub heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    /// fp16 deployment (§V-B: "The NLP results in this paper reflect FP16").
    pub fp16: bool,
    /// int8 serving path: the `d_model`-contraction GEMMs (q/k/v/o
    /// projections + ffn1) run as row-wise quantized FCs on the int8
    /// engine; the wide-contraction ffn2 keeps fp16, mirroring the runtime's
    /// per-layer error-budget fallback.
    pub int8_fc: bool,
}

impl XlmrSpec {
    /// The paper's 24-layer variant: 558 M params.
    pub fn paper() -> Self {
        XlmrSpec {
            layers: 24,
            d_model: 1024,
            heads: 16,
            ffn: 4096,
            vocab: 250_000,
            fp16: true,
            int8_fc: false,
        }
    }

    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_layer = 4 * d * d + 4 * d + 2 * d * self.ffn + self.ffn + d + 4 * d;
        self.vocab * d + self.layers * per_layer + 2 * d
    }
}

fn wdt(spec: &XlmrSpec) -> DType {
    if spec.fp16 {
        DType::F16
    } else {
        DType::F32
    }
}

fn add_matmul(g: &mut Graph, name: &str, x: TensorId, w_rows: usize, w_cols: usize, spec: &XlmrSpec) -> TensorId {
    let xs = g.tensor(x).shape.clone();
    let m = xs.dim(0);
    // int8 serving quantizes the d_model-contraction GEMMs; wider
    // contractions (ffn2, k = ffn) exceed the per-layer error budget and
    // stay on the fp16 engine
    let int8 = spec.int8_fc && w_cols == spec.d_model;
    let dt = if int8 { DType::I8 } else { wdt(spec) };
    let w = g.add_tensor(&format!("{name}.w"), Shape::new(&[w_rows, w_cols]), dt, TensorKind::Weight);
    let y = g.add_tensor(&format!("{name}.y"), Shape::new(&[m, w_rows]), DType::F32, TensorKind::Activation);
    let kind = if int8 { OpKind::QuantizedFc } else { OpKind::MatMul };
    g.add_node(name, kind, vec![x, w], vec![y]);
    y
}

fn add_elem(g: &mut Graph, name: &str, kind: OpKind, ins: Vec<TensorId>, shape: Shape) -> TensorId {
    let y = g.add_tensor(&format!("{name}.y"), shape, DType::F32, TensorKind::Activation);
    g.add_node(name, kind, ins, vec![y]);
    y
}

/// Build an XLM-R style encoder for `batch` sentences of `seq` tokens
/// (already padded to the bucket size, §VI-A).
pub fn xlmr(spec: &XlmrSpec, batch: usize, seq: usize) -> Graph {
    let mut g = Graph::new("xlmr");
    let d = spec.d_model;
    let h = spec.heads;
    let hd = d / h;
    let bs = batch * seq;

    let ids = g.add_tensor("ids", Shape::new(&[batch, seq]), DType::I32, TensorKind::Input);
    let emb_w = g.add_tensor("tok_emb", Shape::new(&[spec.vocab, d]), wdt(spec), TensorKind::Weight);
    let mut x = g.add_tensor("emb", Shape::new(&[bs, d]), DType::F32, TensorKind::Activation);
    g.add_node("embed", OpKind::Gather, vec![emb_w, ids], vec![x]);

    for l in 0..spec.layers {
        let p = format!("l{l}");
        // pre-LN
        let ln_g = g.add_tensor(&format!("{p}.ln1.g"), Shape::new(&[2 * d]), DType::F32, TensorKind::Weight);
        let ln1 = add_elem(&mut g, &format!("{p}.ln1"), OpKind::LayerNorm, vec![x, ln_g], Shape::new(&[bs, d]));
        // QKV projections + output projection: MatMul rows in Table II
        let q = add_matmul(&mut g, &format!("{p}.q"), ln1, d, d, spec);
        let k = add_matmul(&mut g, &format!("{p}.k"), ln1, d, d, spec);
        let v = add_matmul(&mut g, &format!("{p}.v"), ln1, d, d, spec);
        // attention scores + context: BatchMatMul over heads
        let qt = add_elem(&mut g, &format!("{p}.qt"), OpKind::Transpose, vec![q], Shape::new(&[batch * h, seq, hd]));
        let kt = add_elem(&mut g, &format!("{p}.kt"), OpKind::Transpose, vec![k], Shape::new(&[batch * h, hd, seq]));
        let scores = add_elem(&mut g, &format!("{p}.scores"), OpKind::BatchMatMul, vec![qt, kt], Shape::new(&[batch * h, seq, seq]));
        let probs = add_elem(&mut g, &format!("{p}.softmax"), OpKind::Softmax, vec![scores], Shape::new(&[batch * h, seq, seq]));
        let vt = add_elem(&mut g, &format!("{p}.vt"), OpKind::Transpose, vec![v], Shape::new(&[batch * h, seq, hd]));
        let ctx = add_elem(&mut g, &format!("{p}.ctx"), OpKind::BatchMatMul, vec![probs, vt], Shape::new(&[batch * h, seq, hd]));
        let ctx_t = add_elem(&mut g, &format!("{p}.ctx_t"), OpKind::Transpose, vec![ctx], Shape::new(&[bs, d]));
        let o = add_matmul(&mut g, &format!("{p}.o"), ctx_t, d, d, spec);
        let res1 = add_elem(&mut g, &format!("{p}.res1"), OpKind::Add, vec![x, o], Shape::new(&[bs, d]));
        // FFN
        let ln2_g = g.add_tensor(&format!("{p}.ln2.g"), Shape::new(&[2 * d]), DType::F32, TensorKind::Weight);
        let ln2 = add_elem(&mut g, &format!("{p}.ln2"), OpKind::LayerNorm, vec![res1, ln2_g], Shape::new(&[bs, d]));
        let f1 = add_matmul(&mut g, &format!("{p}.ffn1"), ln2, spec.ffn, d, spec);
        let gelu = add_elem(&mut g, &format!("{p}.gelu"), OpKind::Gelu, vec![f1], Shape::new(&[bs, spec.ffn]));
        let f2 = add_matmul(&mut g, &format!("{p}.ffn2"), gelu, d, spec.ffn, spec);
        x = add_elem(&mut g, &format!("{p}.res2"), OpKind::Add, vec![res1, f2], Shape::new(&[bs, d]));
    }

    let lnf_g = g.add_tensor("lnf.g", Shape::new(&[2 * d]), DType::F32, TensorKind::Weight);
    let lnf = add_elem(&mut g, "lnf", OpKind::LayerNorm, vec![x, lnf_g], Shape::new(&[bs, d]));
    let pooled = g.add_tensor("pooled", Shape::new(&[batch, d]), DType::F32, TensorKind::Output);
    g.add_node("pool", OpKind::Concat, vec![lnf], vec![pooled]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_params_and_flops() {
        let spec = XlmrSpec::paper();
        // Table I: 558 MParams
        let p = spec.param_count() as f64 / 1e6;
        assert!(p > 500.0 && p < 620.0, "{p}");
        let g = xlmr(&spec, 1, 32);
        g.validate().unwrap();
        let gf = g.total_flops() / 1e9;
        // Table I: 20 GFLOPs at 32 tokens
        assert!(gf > 12.0 && gf < 30.0, "{gf}");
    }

    #[test]
    fn matmul_dominates_flops() {
        let g = xlmr(&XlmrSpec::paper(), 1, 64);
        let hist = g.op_histogram();
        let total: f64 = hist.values().sum();
        let mm = hist.get("MatMul").copied().unwrap_or(0.0);
        assert!(mm / total > 0.6, "MatMul share {}", mm / total);
    }

    #[test]
    fn flops_scale_superlinearly_with_seq() {
        let g32 = xlmr(&XlmrSpec::paper(), 1, 32);
        let g128 = xlmr(&XlmrSpec::paper(), 1, 128);
        let ratio = g128.total_flops() / g32.total_flops();
        // linear term x4 plus quadratic attention => ratio > 4
        assert!(ratio > 4.0, "{ratio}");
    }

    #[test]
    fn arithmetic_intensity_tracks_tokens() {
        // Table I: AI equals roughly the token count (20-70)
        let g = xlmr(&XlmrSpec::paper(), 1, 32);
        let ai = g.arithmetic_intensity();
        assert!(ai > 10.0 && ai < 80.0, "{ai}");
    }
}
