//! DLRM graph generator at paper scale (§II-A, Table I rows 1–2).
//!
//! The "less complex" model carries ~70 B parameters (dominated by int8/int4
//! embedding tables); the "more complex" one >100 B parameters and ~5× the
//! dense GFLOPs. Dense compute stays in the tens of MFLOPs per batch with
//! arithmetic intensity ~80–90 — the numbers Table I reports.

use crate::graph::models::{add_fc, add_relu};
use crate::graph::ops::OpKind;
use crate::graph::{DType, Graph, Shape, TensorKind};

/// Parameterization of a recommendation model.
#[derive(Debug, Clone)]
pub struct DlrmSpec {
    pub name: &'static str,
    pub num_tables: usize,
    pub rows_per_table: usize,
    pub embed_dim: usize,
    /// Embedding storage type (paper: mixed int8/int4; we model the blend
    /// by letting half the tables be I4 when `mixed_int4` is set).
    pub mixed_int4: bool,
    pub dense_in: usize,
    pub bottom_mlp: Vec<usize>,
    pub top_mlp: Vec<usize>,
    /// Profiled average lookups per table per sample (§VI-B SLS balancing).
    pub avg_lookups: f64,
    pub max_lookups: usize,
    pub quantized_fc: bool,
}

impl DlrmSpec {
    /// "Less complex" Table I row: ~70 B params, ~0.02 GFLOPs/batch-32.
    pub fn base() -> Self {
        DlrmSpec {
            name: "recsys_base",
            num_tables: 24,
            rows_per_table: 45_000_000,
            embed_dim: 64,
            mixed_int4: true,
            dense_in: 256,
            bottom_mlp: vec![128, 64],
            top_mlp: vec![256, 64, 1],
            avg_lookups: 20.0,
            max_lookups: 100,
            quantized_fc: true,
        }
    }

    /// "More complex" Table I row: >100 B params, ~0.1 GFLOPs/batch-32 (the
    /// 5× model of §VII).
    pub fn complex() -> Self {
        DlrmSpec {
            name: "recsys_complex",
            num_tables: 40,
            rows_per_table: 35_000_000,
            embed_dim: 80,
            mixed_int4: true,
            dense_in: 512,
            bottom_mlp: vec![512, 256, 80],
            top_mlp: vec![512, 256, 1],
            avg_lookups: 25.0,
            max_lookups: 120,
            quantized_fc: true,
        }
    }

    pub fn interaction_dim(&self) -> usize {
        let f = self.num_tables + 1;
        self.embed_dim + f * (f - 1) / 2
    }

    pub fn embedding_params(&self) -> usize {
        self.num_tables * self.rows_per_table * self.embed_dim
    }
}

/// Build the DLRM graph for one batch.
pub fn dlrm(spec: &DlrmSpec, batch: usize) -> Graph {
    let mut g = Graph::new(spec.name);

    // ---- inputs -----------------------------------------------------------
    let dense_in = g.add_tensor(
        "dense_features",
        Shape::new(&[batch, spec.dense_in]),
        DType::F16, // §VI-A: dense features shipped fp16 to halve transfer
        TensorKind::Input,
    );
    // fp16 -> fp32 on card
    let dense_f32 = g.add_tensor(
        "dense_f32",
        Shape::new(&[batch, spec.dense_in]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node("convert_dense", OpKind::ConvertTo, vec![dense_in], vec![dense_f32]);

    // ---- embedding lookups (SLS) ------------------------------------------
    let mut pooled = Vec::with_capacity(spec.num_tables);
    for t in 0..spec.num_tables {
        let dt = if spec.mixed_int4 && t % 2 == 0 { DType::I4 } else { DType::I8 };
        let table = g.add_tensor(
            &format!("table{t}"),
            Shape::new(&[spec.rows_per_table, spec.embed_dim]),
            dt,
            TensorKind::Weight,
        );
        let idx = g.add_tensor(
            &format!("idx{t}"),
            Shape::new(&[batch, spec.max_lookups]),
            DType::I32,
            TensorKind::Input,
        );
        let len = g.add_tensor(
            &format!("len{t}"),
            Shape::new(&[batch]),
            DType::I32,
            TensorKind::Input,
        );
        let out = g.add_tensor(
            &format!("pooled{t}"),
            Shape::new(&[batch, spec.embed_dim]),
            DType::F32,
            TensorKind::Activation,
        );
        g.add_node(
            &format!("sls{t}"),
            OpKind::SparseLengthsSum { avg_lookups: spec.avg_lookups },
            vec![table, idx, len],
            vec![out],
        );
        pooled.push(out);
    }
    let sparse = g.add_tensor(
        "sparse_cat",
        Shape::new(&[batch, spec.num_tables, spec.embed_dim]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node("concat_sls", OpKind::Concat, pooled.clone(), vec![sparse]);

    // ---- bottom MLP --------------------------------------------------------
    let mut x = dense_f32;
    for (i, &h) in spec.bottom_mlp.iter().enumerate() {
        x = add_fc(&mut g, &format!("bot_fc{i}"), x, h, spec.quantized_fc);
        x = add_relu(&mut g, &format!("bot_relu{i}"), x);
    }

    // ---- interaction: BatchMatMul of features against themselves ----------
    let f = spec.num_tables + 1;
    let d = spec.embed_dim;
    let feats = g.add_tensor(
        "interact_in",
        Shape::new(&[batch, f, d]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node("concat_feats", OpKind::Concat, vec![x, sparse], vec![feats]);
    let feats_t = g.add_tensor(
        "interact_in_t",
        Shape::new(&[batch, d, f]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node("transpose_feats", OpKind::Transpose, vec![feats], vec![feats_t]);
    let z = g.add_tensor(
        "interact_z",
        Shape::new(&[batch, f, f]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node("interact_bmm", OpKind::BatchMatMul, vec![feats, feats_t], vec![z]);
    let inter = g.add_tensor(
        "interact_flat",
        Shape::new(&[batch, spec.interaction_dim()]),
        DType::F32,
        TensorKind::Activation,
    );
    g.add_node("interact_cat", OpKind::Concat, vec![x, z], vec![inter]);

    // ---- top MLP -----------------------------------------------------------
    let mut y = inter;
    let n_top = spec.top_mlp.len();
    for (i, &h) in spec.top_mlp.iter().enumerate() {
        // §V-B: the last FC stays fp16 (skip-list) even when int8 elsewhere
        let quant = spec.quantized_fc && i + 1 < n_top;
        y = add_fc(&mut g, &format!("top_fc{i}"), y, h, quant);
        if i + 1 < n_top {
            y = add_relu(&mut g, &format!("top_relu{i}"), y);
        }
    }
    let out = g.add_tensor("score", Shape::new(&[batch, 1]), DType::F32, TensorKind::Output);
    g.add_node("sigmoid", OpKind::Sigmoid, vec![y], vec![out]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models::ModelId;

    #[test]
    fn base_matches_table1_scale() {
        let spec = DlrmSpec::base();
        let g = dlrm(&spec, 32);
        g.validate().unwrap();
        let params = g.param_count() as f64;
        // Table I: ~70,000 MParams
        assert!(params > 50e9 && params < 90e9, "{params}");
        let gflops = g.total_flops() / 1e9;
        // Table I: 0.02 GFLOPs per batch — same order of magnitude
        assert!(gflops > 0.005 && gflops < 0.15, "{gflops}");
    }

    #[test]
    fn complex_exceeds_100b_params_and_5x_flops() {
        let base = dlrm(&DlrmSpec::base(), 32);
        let cx = dlrm(&DlrmSpec::complex(), 32);
        cx.validate().unwrap();
        assert!(cx.param_count() > 100_000_000_000, "{}", cx.param_count());
        let ratio = cx.total_flops() / base.total_flops();
        assert!(ratio > 2.5 && ratio < 12.0, "{ratio}");
    }

    #[test]
    fn embedding_tables_dominate_weight_bytes() {
        let g = dlrm(&DlrmSpec::base(), 32);
        let emb_elems = DlrmSpec::base().embedding_params();
        assert!(g.param_count() as f64 / (emb_elems as f64) < 1.01);
    }

    #[test]
    fn mixed_int4_halves_some_tables() {
        let spec = DlrmSpec::base();
        let g = dlrm(&spec, 32);
        // weight bytes must be < pure-int8 bound (since half tables are I4)
        let int8_bound = spec.embedding_params();
        assert!(g.weight_bytes() < int8_bound, "{} vs {}", g.weight_bytes(), int8_bound);
    }

    #[test]
    fn model_id_builders_run() {
        for id in [ModelId::RecsysBase, ModelId::RecsysComplex] {
            let g = id.build();
            g.validate().unwrap();
            assert!(g.nodes.len() > 10);
        }
    }

    #[test]
    fn sls_op_count_matches_tables() {
        let spec = DlrmSpec::base();
        let g = dlrm(&spec, 32);
        let n_sls = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::SparseLengthsSum { .. }))
            .count();
        assert_eq!(n_sls, spec.num_tables);
    }
}
