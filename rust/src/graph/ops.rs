//! Op kinds + FLOPs/bytes accounting.
//!
//! The kinds mirror Glow's node set as reported in the paper's Table II so
//! the simulator's breakdown prints the exact same row labels. `node_flops`
//! / `node_bytes` implement the roofline inputs the op cost model uses.

use super::{Graph, Node};

/// Operation kinds. Parameters that affect cost (groups, strides, average
/// lookups) live on the variant; shapes come from the tensors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpKind {
    /// Fully connected: inputs [x(m,k), w(n,k), b(n)] -> [y(m,n)].
    Fc,
    /// Int8 FC: inputs [x, wq, scale, zp, b] -> y. Runs on the Matrix Engine.
    QuantizedFc,
    /// SparseLengthsSum: inputs [table, indices, lengths] -> pooled.
    /// `avg_lookups` is the profiled average the load balancer uses (§VI-B
    /// "Optimizing Sparse Lookups"); cost scales with it at runtime.
    SparseLengthsSum { avg_lookups: f64 },
    /// Single-lookup SLS specialization (§VI-B): plain row copy.
    SparseLengthsSumSingle,
    /// Batched matmul: [a(b,m,k), b(b,k,n)] -> [c(b,m,n)].
    BatchMatMul,
    /// Unbatched matmul (NLP attention projections in Table II are "MatMul").
    MatMul,
    /// 2D convolution; `groups > 1` covers the channelwise/groupwise convs
    /// that dominate ResNeXt/RegNetY/FBNetV3 (Table II). Quantized variants
    /// use the int8 engine.
    Conv { groups: usize, stride: usize, kh: usize, kw: usize, quantized: bool },
    /// Conv fused with the following Add (vendor-level fusion, Table II
    /// "Fused Conv_Add").
    ConvAddFused { groups: usize, stride: usize, kh: usize, kw: usize, quantized: bool },
    /// 3D convolution (video trunk).
    Conv3D { groups: usize, kt: usize, kh: usize, kw: usize },
    Add,
    Mul,
    Concat,
    Transpose,
    /// Broadcast along batch (recsys input replication, §VI-A).
    Tile,
    Quantize,
    Dequantize,
    /// dtype conversion (fp32<->fp16) — "ConvertTo" in Table II.
    ConvertTo,
    AvgPool { kh: usize, kw: usize, optimized: bool },
    AdaptiveAvgPool { optimized: bool },
    MaxPool { kh: usize, kw: usize },
    Relu,
    Gelu,
    Swish,
    Sigmoid,
    Softmax,
    LayerNorm,
    BatchNorm,
    /// Detection-head ops that stay on the host CPU in the paper (§VI-A).
    RoiAlign,
    NonMaxSuppression,
    /// Embedding lookup for NLP token embeddings.
    Gather,
}

impl OpKind {
    /// Row label used in the paper's Table II.
    pub fn table_name(&self) -> &'static str {
        match self {
            OpKind::Fc | OpKind::QuantizedFc => "FC",
            OpKind::SparseLengthsSum { .. } | OpKind::SparseLengthsSumSingle => "SLS",
            OpKind::BatchMatMul => "BatchMatMul",
            OpKind::MatMul => "MatMul",
            OpKind::Conv { groups, quantized, .. } => {
                if *groups > 1 {
                    "ChannelwiseQuantizedConv"
                } else if *quantized {
                    "QuantizedConv"
                } else {
                    "Convolution"
                }
            }
            OpKind::ConvAddFused { .. } => "Fused Conv_Add",
            OpKind::Conv3D { .. } => "Convolution3D",
            OpKind::Add => "Add",
            OpKind::Mul => "Mul",
            OpKind::Concat => "Concat",
            OpKind::Transpose => "Transpose",
            OpKind::Tile => "Tile",
            OpKind::Quantize => "Quantize",
            OpKind::Dequantize => "Dequantize",
            OpKind::ConvertTo => "ConvertTo",
            OpKind::AvgPool { .. } | OpKind::AdaptiveAvgPool { .. } => "AdaptiveAvgPool",
            OpKind::MaxPool { .. } => "MaxPool",
            OpKind::Relu => "Relu",
            OpKind::Gelu => "Gelu",
            OpKind::Swish => "Swish",
            OpKind::Sigmoid => "Sigmoid",
            OpKind::Softmax => "Softmax",
            OpKind::LayerNorm => "LayerNorm",
            OpKind::BatchNorm => "BatchNorm",
            OpKind::RoiAlign => "ROIAlign",
            OpKind::NonMaxSuppression => "NMS",
            OpKind::Gather => "Gather",
        }
    }

    /// Which compute engine the op maps to (decides peak throughput and
    /// whether int8 speedup applies). §III-B: Matrix Engine vs Vector Core.
    pub fn engine(&self) -> Engine {
        match self {
            OpKind::Fc
            | OpKind::QuantizedFc
            | OpKind::BatchMatMul
            | OpKind::MatMul
            | OpKind::Conv { .. }
            | OpKind::ConvAddFused { .. }
            | OpKind::Conv3D { .. } => Engine::Matrix,
            OpKind::RoiAlign | OpKind::NonMaxSuppression => Engine::Host,
            _ => Engine::Vector,
        }
    }

    /// True if the op's math runs in int8 on the Matrix Engine.
    pub fn is_int8(&self) -> bool {
        matches!(
            self,
            OpKind::QuantizedFc
                | OpKind::Conv { quantized: true, .. }
                | OpKind::ConvAddFused { quantized: true, .. }
        )
    }

    /// True for ops the paper keeps on the host CPU (§VI-A).
    pub fn host_only(&self) -> bool {
        self.engine() == Engine::Host
    }
}

/// Compute engine classes on the card (plus the host CPU fallback).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    Matrix,
    Vector,
    Host,
}

fn t_elems(g: &Graph, n: &Node, i: usize) -> f64 {
    g.tensor(n.inputs[i]).shape.elements() as f64
}

fn out_elems(g: &Graph, n: &Node) -> f64 {
    n.outputs.iter().map(|&o| g.tensor(o).shape.elements() as f64).sum()
}

/// FLOPs for one execution of `n` (multiply-add = 2 flops).
pub fn node_flops(g: &Graph, n: &Node) -> f64 {
    match &n.kind {
        OpKind::Fc | OpKind::QuantizedFc => {
            // x: [m,k], w: [n,k]
            let x = &g.tensor(n.inputs[0]).shape;
            let w = &g.tensor(n.inputs[1]).shape;
            2.0 * x.dim(0) as f64 * w.dim(0) as f64 * w.dim(1) as f64
        }
        OpKind::SparseLengthsSum { avg_lookups } => {
            // pooled output [b, d]; each pooled row sums avg_lookups rows
            out_elems(g, n) * avg_lookups
        }
        OpKind::SparseLengthsSumSingle => out_elems(g, n),
        OpKind::BatchMatMul => {
            let a = &g.tensor(n.inputs[0]).shape; // [b, m, k]
            let b = &g.tensor(n.inputs[1]).shape; // [b, k, n]
            2.0 * a.dim(0) as f64 * a.dim(1) as f64 * a.dim(2) as f64
                * b.dim(b.rank() - 1) as f64
        }
        OpKind::MatMul => {
            // [m, k] x weight -> [m, n]; contraction dim = a.dim(1)
            let a = &g.tensor(n.inputs[0]).shape;
            2.0 * a.dim(1) as f64 * out_elems(g, n)
        }
        OpKind::Conv { groups, kh, kw, .. } | OpKind::ConvAddFused { groups, kh, kw, .. } => {
            // out: [n, h, w, cout]; in channels from input tensor
            let out = &g.tensor(n.outputs[0]).shape;
            let cin = g.tensor(n.inputs[0]).shape.last();
            2.0 * out.elements() as f64 * (cin / groups) as f64 * (*kh * *kw) as f64
        }
        OpKind::Conv3D { groups, kt, kh, kw } => {
            let out = &g.tensor(n.outputs[0]).shape;
            let cin = g.tensor(n.inputs[0]).shape.last();
            2.0 * out.elements() as f64 * (cin / groups) as f64 * (*kt * *kh * *kw) as f64
        }
        OpKind::Softmax => 5.0 * out_elems(g, n),
        OpKind::Gelu | OpKind::Swish => 8.0 * out_elems(g, n),
        OpKind::LayerNorm | OpKind::BatchNorm => 6.0 * out_elems(g, n),
        OpKind::AvgPool { kh, kw, .. } => out_elems(g, n) * (*kh * *kw) as f64,
        OpKind::AdaptiveAvgPool { .. } => t_elems(g, n, 0),
        OpKind::MaxPool { kh, kw } => out_elems(g, n) * (*kh * *kw) as f64,
        OpKind::RoiAlign => 16.0 * out_elems(g, n),
        OpKind::NonMaxSuppression => 8.0 * t_elems(g, n, 0),
        // element-wise / data-movement: 1 flop per output element (or 0 for
        // pure movement, counted as small constant to keep shares sane)
        OpKind::Add | OpKind::Mul | OpKind::Relu | OpKind::Sigmoid => out_elems(g, n),
        OpKind::Quantize | OpKind::Dequantize | OpKind::ConvertTo => out_elems(g, n),
        OpKind::Concat | OpKind::Transpose | OpKind::Tile | OpKind::Gather => 0.0,
    }
}

/// Bytes moved for one execution of `n`: all inputs read + outputs written.
/// Weight reads count at their stored precision (int8/int4 tables!).
pub fn node_bytes(g: &Graph, n: &Node) -> f64 {
    let read: usize = n
        .inputs
        .iter()
        .map(|&t| {
            let ten = g.tensor(t);
            match n.kind {
                // SLS reads only avg_lookups rows per pooled row, not the
                // whole table — the defining memory behaviour of recsys.
                OpKind::SparseLengthsSum { avg_lookups } if ten.kind == super::TensorKind::Weight => {
                    let d = ten.shape.last();
                    let rows_read = g.tensor(n.outputs[0]).shape.dim(0) as f64 * avg_lookups;
                    ten.dtype.bytes_for((rows_read * d as f64) as usize)
                }
                OpKind::SparseLengthsSumSingle if ten.kind == super::TensorKind::Weight => {
                    let d = ten.shape.last();
                    ten.dtype.bytes_for(g.tensor(n.outputs[0]).shape.dim(0) * d)
                }
                _ => ten.bytes(),
            }
        })
        .sum();
    let written: usize = n.outputs.iter().map(|&t| g.tensor(t).bytes()).sum();
    (read + written) as f64
}

trait ShapeExt {
    fn last(&self) -> usize;
}

impl ShapeExt for super::Shape {
    fn last(&self) -> usize {
        *self.0.last().unwrap_or(&1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Shape, TensorKind};

    #[test]
    fn fc_flops() {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", Shape::new(&[4, 8]), DType::F32, TensorKind::Input);
        let w = g.add_tensor("w", Shape::new(&[16, 8]), DType::F32, TensorKind::Weight);
        let b = g.add_tensor("b", Shape::new(&[16]), DType::F32, TensorKind::Weight);
        let y = g.add_tensor("y", Shape::new(&[4, 16]), DType::F32, TensorKind::Activation);
        let n = g.add_node("fc", OpKind::Fc, vec![x, w, b], vec![y]);
        assert_eq!(node_flops(&g, g.node(n)), 2.0 * 4.0 * 16.0 * 8.0);
    }

    #[test]
    fn sls_bytes_scale_with_lookups_not_table() {
        let mut g = Graph::new("t");
        let table =
            g.add_tensor("tab", Shape::new(&[1_000_000, 64]), DType::I8, TensorKind::Weight);
        let idx = g.add_tensor("idx", Shape::new(&[32, 100]), DType::I32, TensorKind::Input);
        let len = g.add_tensor("len", Shape::new(&[32]), DType::I32, TensorKind::Input);
        let out = g.add_tensor("o", Shape::new(&[32, 64]), DType::F32, TensorKind::Activation);
        let n = g.add_node(
            "sls",
            OpKind::SparseLengthsSum { avg_lookups: 20.0 },
            vec![table, idx, len],
            vec![out],
        );
        let bytes = node_bytes(&g, g.node(n));
        // table rows read: 32*20 rows * 64 B (i8) = 40960, NOT 64 MB
        assert!(bytes < 100_000.0, "{bytes}");
        assert!(bytes > 32.0 * 20.0 * 64.0, "{bytes}");
    }

    #[test]
    fn grouped_conv_table_name() {
        let k = OpKind::Conv { groups: 8, stride: 1, kh: 3, kw: 3, quantized: true };
        assert_eq!(k.table_name(), "ChannelwiseQuantizedConv");
        let k2 = OpKind::Conv { groups: 1, stride: 1, kh: 3, kw: 3, quantized: true };
        assert_eq!(k2.table_name(), "QuantizedConv");
    }

    #[test]
    fn conv_flops_account_for_groups() {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", Shape::new(&[1, 8, 8, 16]), DType::F32, TensorKind::Input);
        let w = g.add_tensor("w", Shape::new(&[3, 3, 2, 16]), DType::F32, TensorKind::Weight);
        let y = g.add_tensor("y", Shape::new(&[1, 8, 8, 16]), DType::F32, TensorKind::Activation);
        let dense = g.add_node(
            "c1",
            OpKind::Conv { groups: 1, stride: 1, kh: 3, kw: 3, quantized: false },
            vec![x, w],
            vec![y],
        );
        let y2 = g.add_tensor("y2", Shape::new(&[1, 8, 8, 16]), DType::F32, TensorKind::Activation);
        let grouped = g.add_node(
            "c2",
            OpKind::Conv { groups: 8, stride: 1, kh: 3, kw: 3, quantized: false },
            vec![x, w],
            vec![y2],
        );
        let f1 = node_flops(&g, g.node(dense));
        let f2 = node_flops(&g, g.node(grouped));
        assert!((f1 / f2 - 8.0).abs() < 1e-9, "{f1} {f2}");
    }

    #[test]
    fn engines() {
        assert_eq!(OpKind::Fc.engine(), Engine::Matrix);
        assert_eq!(OpKind::Softmax.engine(), Engine::Vector);
        assert_eq!(OpKind::RoiAlign.engine(), Engine::Host);
        assert!(OpKind::QuantizedFc.is_int8());
        assert!(!OpKind::Fc.is_int8());
    }
}
