//! Glow-like compute-graph IR (§IV-C of the paper).
//!
//! The IR is deliberately close to Glow's node set: the op kinds are the
//! ones the paper's Table II reports (FC, SparseLengthsSum, BatchMatMul,
//! ChannelwiseQuantizedConv, …) so the simulator's per-op breakdown prints
//! the same rows. Tensors are *descriptors* (shape + dtype + placement
//! class); actual numerics run through the PJRT runtime, not this IR.

pub mod models;
pub mod ops;

use ops::OpKind;
use std::collections::{BTreeMap, HashSet};

/// Element types, including the packed 4-bit type used for embedding-table
/// compression ([18] in the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    Bf16,
    I32,
    I8,
    /// 4-bit quantized (packed two per byte) + per-row scale/bias — the
    /// mixed int8/int4 embedding format of §V-B.
    I4,
}

impl DType {
    /// Bytes per element (I4 counts 0.5, so use `bytes_for(n)`).
    pub fn bits(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 32,
            DType::F16 | DType::Bf16 => 16,
            DType::I8 => 8,
            DType::I4 => 4,
        }
    }

    pub fn bytes_for(self, elements: usize) -> usize {
        (elements * self.bits()).div_ceil(8)
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::Bf16 => "bf16",
            DType::I32 => "i32",
            DType::I8 => "i8",
            DType::I4 => "i4",
        }
    }
}

/// Dense tensor shape.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    pub fn elements(&self) -> usize {
        self.0.iter().product()
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Dimension `i`. Panics with the full shape in the message on an
    /// out-of-range axis, so a bad rank assumption names itself instead of
    /// surfacing as a bare slice index.
    pub fn dim(&self, i: usize) -> usize {
        match self.0.get(i) {
            Some(&d) => d,
            None => panic!("shape dim {i} out of range for rank-{} shape {:?}", self.rank(), self.0),
        }
    }
}

/// What a tensor is, for placement/transfer purposes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// Model weight: persistent, placed at load time (LPDDR or SRAM).
    Weight,
    /// Request input arriving over PCIe from the host.
    Input,
    /// Intermediate activation.
    Activation,
    /// Net output returning to the host.
    Output,
}

pub type TensorId = usize;
pub type NodeId = usize;

/// Tensor descriptor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub id: TensorId,
    pub name: String,
    pub shape: Shape,
    pub dtype: DType,
    pub kind: TensorKind,
}

impl Tensor {
    pub fn bytes(&self) -> usize {
        self.dtype.bytes_for(self.shape.elements())
    }
}

/// One operation.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

/// The compute graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub tensors: Vec<Tensor>,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Graph { name: name.to_string(), tensors: Vec::new(), nodes: Vec::new() }
    }

    pub fn add_tensor(&mut self, name: &str, shape: Shape, dtype: DType, kind: TensorKind) -> TensorId {
        let id = self.tensors.len();
        self.tensors.push(Tensor { id, name: name.to_string(), shape, dtype, kind });
        id
    }

    pub fn add_node(&mut self, name: &str, kind: OpKind, inputs: Vec<TensorId>, outputs: Vec<TensorId>) -> NodeId {
        let id = self.nodes.len();
        debug_assert!(inputs.iter().chain(&outputs).all(|&t| t < self.tensors.len()));
        self.nodes.push(Node { id, name: name.to_string(), kind, inputs, outputs });
        id
    }

    /// Tensor by id. Panics with the graph name and id on a dangling
    /// reference — the analyzer's `structural-invalid` lint catches these
    /// without panicking; this message is for code that indexes directly.
    pub fn tensor(&self, id: TensorId) -> &Tensor {
        match self.tensors.get(id) {
            Some(t) => t,
            None => panic!(
                "tensor id {id} out of range for graph '{}' ({} tensors)",
                self.name,
                self.tensors.len()
            ),
        }
    }

    /// Node by id. Panics with the graph name and id on an out-of-range id.
    pub fn node(&self, id: NodeId) -> &Node {
        match self.nodes.get(id) {
            Some(n) => n,
            None => panic!(
                "node id {id} out of range for graph '{}' ({} nodes)",
                self.name,
                self.nodes.len()
            ),
        }
    }

    /// The node producing each tensor (None for graph inputs/weights).
    pub fn producers(&self) -> Vec<Option<NodeId>> {
        let mut p = vec![None; self.tensors.len()];
        for n in &self.nodes {
            for &o in &n.outputs {
                p[o] = Some(n.id);
            }
        }
        p
    }

    /// Consumers of each tensor.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut c = vec![Vec::new(); self.tensors.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                c[i].push(n.id);
            }
        }
        c
    }

    /// Topological order of node ids; Err if the graph has a cycle.
    pub fn topo_order(&self) -> Result<Vec<NodeId>, GraphError> {
        let producers = self.producers();
        let mut indeg = vec![0usize; self.nodes.len()];
        let mut succs: Vec<Vec<NodeId>> = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                if let Some(p) = producers[i] {
                    succs[p].push(n.id);
                    indeg[n.id] += 1;
                }
            }
        }
        let mut ready: Vec<NodeId> =
            (0..self.nodes.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(n) = ready.pop() {
            order.push(n);
            for &s in &succs[n] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.push(s);
                }
            }
        }
        if order.len() != self.nodes.len() {
            return Err(GraphError::Cycle);
        }
        Ok(order)
    }

    /// Structural validation: unique producers, no dangling ids, acyclic,
    /// weights never written.
    pub fn validate(&self) -> Result<(), GraphError> {
        let mut produced: HashSet<TensorId> = HashSet::new();
        for n in &self.nodes {
            for &t in n.inputs.iter().chain(&n.outputs) {
                if t >= self.tensors.len() {
                    return Err(GraphError::DanglingTensor { node: n.id, tensor: t });
                }
            }
            for &o in &n.outputs {
                if !produced.insert(o) {
                    return Err(GraphError::MultipleProducers { tensor: o });
                }
                match self.tensors[o].kind {
                    TensorKind::Weight | TensorKind::Input => {
                        return Err(GraphError::WriteToConstant { node: n.id, tensor: o })
                    }
                    _ => {}
                }
            }
        }
        self.topo_order()?;
        Ok(())
    }

    /// Total weight bytes (what must fit in card memory — §VI-B motivation
    /// for model-parallel partitioning).
    pub fn weight_bytes(&self) -> usize {
        self.tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Weight)
            .map(|t| t.bytes())
            .sum()
    }

    /// Total parameters (elements of weight tensors).
    pub fn param_count(&self) -> usize {
        self.tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Weight)
            .map(|t| t.shape.elements())
            .sum()
    }

    /// FLOPs of one execution of the whole graph.
    pub fn total_flops(&self) -> f64 {
        self.nodes.iter().map(|n| ops::node_flops(self, n)).sum()
    }

    /// Bytes moved by one execution (weights + activations read + written).
    pub fn total_bytes(&self) -> f64 {
        self.nodes.iter().map(|n| ops::node_bytes(self, n)).sum()
    }

    /// Arithmetic intensity (ops per byte) — Table I column. Defined as the
    /// paper does: FLOPs over (weights + activations), each tensor counted
    /// once at its stored precision. Embedding tables count only the rows an
    /// execution actually touches (SLS gathers, not whole tables) — that is
    /// the access pattern §II-A describes. Per-node traffic for the roofline
    /// model is `total_bytes`, a different quantity.
    pub fn arithmetic_intensity(&self) -> f64 {
        let mut seen: HashSet<TensorId> = HashSet::new();
        let mut bytes = 0.0f64;
        // weights touched by gather-style ops: count rows read, once
        for n in &self.nodes {
            if let ops::OpKind::SparseLengthsSum { avg_lookups } = n.kind {
                let table = &self.tensors[n.inputs[0]];
                if seen.insert(table.id) {
                    let d = table.shape.0.last().copied().unwrap_or(1);
                    let rows = self.tensor(n.outputs[0]).shape.dim(0) as f64 * avg_lookups;
                    bytes += table.dtype.bytes_for((rows * d as f64) as usize) as f64;
                }
            }
        }
        for t in &self.tensors {
            if seen.contains(&t.id) || t.kind != TensorKind::Weight {
                continue;
            }
            bytes += t.bytes() as f64;
        }
        // activations: peak live footprint (producer out + in), not the sum
        // over the whole net — intermediates are reused in place.
        let max_act = self
            .tensors
            .iter()
            .filter(|t| t.kind != TensorKind::Weight)
            .map(|t| t.bytes() as f64)
            .fold(0.0, f64::max);
        bytes += 2.0 * max_act;
        if bytes == 0.0 {
            0.0
        } else {
            self.total_flops() / bytes
        }
    }

    /// Per-op-kind share of total FLOPs-weighted cost; used for Table II
    /// *static* estimates (the simulator produces the measured ones).
    pub fn op_histogram(&self) -> BTreeMap<&'static str, f64> {
        let mut h: BTreeMap<&'static str, f64> = BTreeMap::new();
        for n in &self.nodes {
            *h.entry(n.kind.table_name()).or_insert(0.0) += ops::node_flops(self, n);
        }
        h
    }
}

/// Graph structural errors.
#[derive(Debug, PartialEq, Eq)]
pub enum GraphError {
    Cycle,
    DanglingTensor { node: NodeId, tensor: TensorId },
    MultipleProducers { tensor: TensorId },
    WriteToConstant { node: NodeId, tensor: TensorId },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Cycle => write!(f, "graph contains a cycle"),
            GraphError::DanglingTensor { node, tensor } => {
                write!(f, "node {node} references dangling tensor {tensor}")
            }
            GraphError::MultipleProducers { tensor } => {
                write!(f, "tensor {tensor} has multiple producers")
            }
            GraphError::WriteToConstant { node, tensor } => {
                write!(f, "node {node} writes to weight/input tensor {tensor}")
            }
        }
    }
}

impl std::error::Error for GraphError {}

#[cfg(test)]
mod tests {
    use super::*;
    use ops::OpKind;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny");
        let x = g.add_tensor("x", Shape::new(&[4, 8]), DType::F32, TensorKind::Input);
        let w = g.add_tensor("w", Shape::new(&[16, 8]), DType::F32, TensorKind::Weight);
        let b = g.add_tensor("b", Shape::new(&[16]), DType::F32, TensorKind::Weight);
        let y = g.add_tensor("y", Shape::new(&[4, 16]), DType::F32, TensorKind::Output);
        g.add_node("fc", OpKind::Fc, vec![x, w, b], vec![y]);
        g
    }

    #[test]
    fn tiny_graph_validates() {
        let g = tiny();
        g.validate().unwrap();
        assert_eq!(g.param_count(), 16 * 8 + 16);
        assert!(g.total_flops() > 0.0);
        assert!(g.arithmetic_intensity() > 0.0);
    }

    #[test]
    #[should_panic(expected = "tensor id 99 out of range for graph 'tiny'")]
    fn tensor_access_panics_with_context() {
        let g = tiny();
        let _ = g.tensor(99);
    }

    #[test]
    #[should_panic(expected = "node id 7 out of range for graph 'tiny'")]
    fn node_access_panics_with_context() {
        let g = tiny();
        let _ = g.node(7);
    }

    #[test]
    #[should_panic(expected = "shape dim 3 out of range for rank-2 shape")]
    fn shape_dim_panics_with_context() {
        let _ = Shape::new(&[4, 8]).dim(3);
    }

    #[test]
    fn cycle_detected() {
        let mut g = Graph::new("cyc");
        let a = g.add_tensor("a", Shape::new(&[1]), DType::F32, TensorKind::Activation);
        let b = g.add_tensor("b", Shape::new(&[1]), DType::F32, TensorKind::Activation);
        g.add_node("n1", OpKind::Relu, vec![a], vec![b]);
        g.add_node("n2", OpKind::Relu, vec![b], vec![a]);
        assert_eq!(g.validate(), Err(GraphError::Cycle));
    }

    #[test]
    fn multiple_producers_detected() {
        let mut g = Graph::new("mp");
        let a = g.add_tensor("a", Shape::new(&[1]), DType::F32, TensorKind::Input);
        let b = g.add_tensor("b", Shape::new(&[1]), DType::F32, TensorKind::Activation);
        g.add_node("n1", OpKind::Relu, vec![a], vec![b]);
        g.add_node("n2", OpKind::Relu, vec![a], vec![b]);
        assert!(matches!(g.validate(), Err(GraphError::MultipleProducers { .. })));
    }

    #[test]
    fn write_to_weight_detected() {
        let mut g = Graph::new("ww");
        let a = g.add_tensor("a", Shape::new(&[1]), DType::F32, TensorKind::Input);
        let w = g.add_tensor("w", Shape::new(&[1]), DType::F32, TensorKind::Weight);
        g.add_node("n1", OpKind::Relu, vec![a], vec![w]);
        assert!(matches!(g.validate(), Err(GraphError::WriteToConstant { .. })));
    }

    #[test]
    fn topo_order_respects_deps() {
        let g = tiny();
        let order = g.topo_order().unwrap();
        assert_eq!(order.len(), 1);
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::I4.bytes_for(10), 5);
        assert_eq!(DType::I4.bytes_for(11), 6);
        assert_eq!(DType::F16.bytes_for(3), 6);
    }
}
