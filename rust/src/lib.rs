//! # fbia — First-generation Inference Accelerator platform (reproduction)
//!
//! A production-shaped reproduction of *"First-Generation Inference
//! Accelerator Deployment at Facebook"* (CS.AR 2021): a three-layer
//! Rust + JAX + Pallas stack in which
//!
//! * **Layer 1/2 (build-time Python)** author the models (DLRM, mini XLM-R,
//!   CV trunk) and their Pallas compute kernels, AOT-lowered to HLO text
//!   under `artifacts/`;
//! * **Layer 3 (this crate)** is everything that serves: a Glow-like graph
//!   compiler ([`compiler`]), a parameterized six-card accelerator-node
//!   simulator ([`sim`] + [`platform`]), a runtime with pluggable execution
//!   backends ([`runtime`] — a hermetic pure-Rust reference interpreter by
//!   default, PJRT execution of the AOT artifacts behind `--features
//!   pjrt`), quantization/reference numerics ([`numerics`]), the
//!   serving stack ([`serving`]), a static analyzer ([`analysis`])
//!   that proves shape/dtype consistency and memory fit and vets
//!   deployment configs before anything is prepared or simulated, and an
//!   observability layer ([`obs`]) that attributes every request's modeled
//!   latency to pipeline stages and exports Perfetto-loadable traces.
//!
//! Python is never on the request path — and with the builtin manifest
//! ([`runtime::builtin`]) it is not needed at build time either: the
//! default `cargo build` serves DLRM/XLM-R/CV out of the box, fully
//! offline. See rust/README.md for the backend matrix.
//!
//! See `DESIGN.md` for the substitution table (what the paper had vs. what
//! this repo builds) and the experiment index mapping every paper table and
//! figure to a bench target.

pub mod analysis;
pub mod capacity;
pub mod compiler;
pub mod config;
pub mod graph;
pub mod numerics;
pub mod obs;
pub mod platform;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod util;
pub mod workloads;

/// Crate version string used by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
