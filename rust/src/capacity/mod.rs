//! Capacity planning model (Fig. 1): servers needed for inference as demand
//! grows, CPU-only vs accelerator-augmented fleets.
//!
//! Fig. 1 reports 5–7× growth in inference servers over two years for (a)
//! recommendation and (b) other ML. This module reproduces the *series*: a
//! demand-growth model converted to server counts through each platform's
//! measured per-server throughput, normalized like the paper's y-axis.

use crate::config::Config;
use crate::graph::models::ModelId;
use crate::sim::simulate_model;
use crate::util::error::Result;

/// One growth scenario.
#[derive(Debug, Clone)]
pub struct GrowthScenario {
    pub name: &'static str,
    /// demand multiplier per quarter.
    pub quarterly_growth: f64,
    pub quarters: usize,
    /// demand at t=0, requests/sec.
    pub initial_qps: f64,
}

impl GrowthScenario {
    /// Fig. 1a: recommendation — ~6x over 8 quarters => 1.25x/quarter.
    pub fn recommendation() -> Self {
        GrowthScenario {
            name: "recommendation",
            quarterly_growth: 1.25,
            quarters: 8,
            initial_qps: 200_000.0,
        }
    }

    /// Fig. 1b: other ML (CV/text) — ~5x over 8 quarters.
    pub fn other_ml() -> Self {
        GrowthScenario {
            name: "cv+text",
            quarterly_growth: 1.22,
            quarters: 8,
            initial_qps: 50_000.0,
        }
    }

    pub fn demand_at(&self, quarter: usize) -> f64 {
        self.initial_qps * self.quarterly_growth.powi(quarter as i32)
    }
}

/// One point of the capacity series.
#[derive(Debug, Clone)]
pub struct CapacityPoint {
    pub quarter: usize,
    pub demand_qps: f64,
    pub cpu_servers: f64,
    pub accel_servers: f64,
    /// normalized like Fig. 1 (servers at t / servers at t=0).
    pub cpu_norm: f64,
    pub accel_norm: f64,
}

/// Convert a demand curve into server counts given each platform's
/// per-server throughput — the Fig. 1 arithmetic, factored out so the
/// accelerator throughput can come from *either* a single-model simulation
/// ([`capacity_series`]) or the fleet router's measured per-node QPS on a
/// mixed trace ([`crate::serving::fleet::plan::plan_capacity`]).
pub fn series_from_qps(
    scenario: &GrowthScenario,
    accel_qps_per_server: f64,
    cpu_qps_per_server: f64,
) -> Vec<CapacityPoint> {
    let mut out = Vec::new();
    let d0 = scenario.demand_at(0);
    // normalization uses the raw (un-floored) series so the Fig. 1 y-axis
    // (growth relative to t=0) is not distorted by the 1-server floor
    let cpu0 = d0 / cpu_qps_per_server;
    let acc0 = d0 / accel_qps_per_server;
    for q in 0..=scenario.quarters {
        let d = scenario.demand_at(q);
        let cpu = d / cpu_qps_per_server;
        let acc = d / accel_qps_per_server;
        out.push(CapacityPoint {
            quarter: q,
            demand_qps: d,
            cpu_servers: cpu.max(1.0),
            accel_servers: acc.max(1.0),
            cpu_norm: cpu / cpu0,
            accel_norm: acc / acc0,
        });
    }
    out
}

/// CPU-only per-server throughput for one model: serve its FLOPs on the
/// host's sustained GFLOPs (optimistic for the CPU; the paper's point is
/// that complex models "cannot be easily or efficiently run on CPUs").
pub fn cpu_qps_per_server(model: ModelId, cfg: &Config) -> f64 {
    let g = model.build();
    (cfg.node.host.gflops * 1e9 * 0.5) / g.total_flops() * model.typical_batch() as f64
}

/// Per-server throughput assumptions. CPU throughput is derived from the
/// host model in the config; accelerator throughput from the single-model
/// simulator. (The `fbia fleet`/`fbia capacity` path instead measures the
/// accelerator side with the fleet router on a mixed trace.)
pub fn capacity_series(model: ModelId, scenario: &GrowthScenario, cfg: &Config) -> Result<Vec<CapacityPoint>> {
    let accel = simulate_model(model, cfg, 200)?;
    Ok(series_from_qps(scenario, accel.items_per_s, cpu_qps_per_server(model, cfg)))
}

/// Power saved by serving the demand on accelerators instead of CPUs, watts.
pub fn power_savings(points: &[CapacityPoint], cfg: &Config) -> f64 {
    let last = match points.last() {
        Some(p) => p,
        None => return 0.0,
    };
    let cpu_server_w = 300.0; // dual-socket-class serving node
    let accel_server_w = 150.0 + cfg.node.accel_power_w(); // host + cards
    last.cpu_servers * cpu_server_w - last.accel_servers * accel_server_w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn growth_matches_fig1_band() {
        // Fig. 1: 5-7x growth over the window
        for s in [GrowthScenario::recommendation(), GrowthScenario::other_ml()] {
            let ratio = s.demand_at(s.quarters) / s.demand_at(0);
            assert!(ratio > 4.5 && ratio < 7.5, "{}: {ratio}", s.name);
        }
    }

    #[test]
    fn accel_needs_fewer_servers() {
        let cfg = Config::default();
        let pts = capacity_series(ModelId::RecsysComplex, &GrowthScenario::recommendation(), &cfg)
            .unwrap();
        for p in &pts {
            assert!(p.accel_servers <= p.cpu_servers, "{p:?}");
        }
        // normalized growth identical (same demand curve)
        let last = pts.last().unwrap();
        assert!((last.cpu_norm - last.accel_norm).abs() / last.cpu_norm < 0.2);
    }

    #[test]
    fn series_monotone() {
        let cfg = Config::default();
        let pts =
            capacity_series(ModelId::XlmR, &GrowthScenario::other_ml(), &cfg).unwrap();
        for w in pts.windows(2) {
            assert!(w[1].demand_qps > w[0].demand_qps);
            assert!(w[1].accel_servers >= w[0].accel_servers);
        }
    }

    #[test]
    fn power_savings_positive_for_complex_models() {
        let cfg = Config::default();
        let pts = capacity_series(ModelId::RegNetY, &GrowthScenario::other_ml(), &cfg).unwrap();
        assert!(power_savings(&pts, &cfg) > 0.0);
    }
}
