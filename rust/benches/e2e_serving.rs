//! Bench: end-to-end serving over the engine's execution backend (L3 hot
//! path) — the reference interpreter by default, PJRT with `--features
//! pjrt` + built artifacts.
//!
//! Times the actual request path — artifact execution, partition pipeline,
//! batcher — and prints throughput/latency per model family. This is the
//! harness the §Perf optimization loop measures against.
//!
//!     cargo bench --bench e2e_serving [-- --threads 4 --backend sim]
//!
//! `--threads` sets the multi-thread point (request workers, and the DLRM
//! intra-request SLS shard fan-out) reported next to the sequential rows.
//! `--backend {ref,sim,pjrt}` selects execution; `sim` reports modeled
//! card latencies instead of host wall time.

use fbia::runtime::Engine;
use fbia::serving::{CvServer, NlpServer, RecsysServer, ServeOptions};
use fbia::util::bench::{bench_with, report, section};
use fbia::util::cli::Args;
use fbia::util::table::{ms, pct, Table};
use fbia::workloads::{CvGen, NlpGen, RecsysGen};
use std::sync::Arc;

fn main() {
    let args = Args::from_env(false);
    let threads = args.get_usize("threads", 4).max(1);
    // the multi-thread point next to each sequential row (no duplicate
    // rows when --threads 1)
    let thread_points: Vec<usize> = if threads > 1 { vec![1, threads] } else { vec![1] };
    // cargo runs bench binaries with cwd = rust/; artifacts/ lives at the
    // repository root, one level up
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let engine = Arc::new(Engine::auto_with(&dir, args.get("backend")).expect("engine"));
    println!(
        "backend: {} ({} devices, {} clock)",
        engine.backend_name(),
        engine.device_count(),
        engine.clock().name()
    );
    let m = engine.manifest().clone();

    section("E2E: DLRM partitioned serving (real numerics)");
    {
        let batch = 32;
        let mut gen = RecsysGen::from_manifest(1, batch, &m).unwrap();
        let reqs: Vec<_> = (0..24).map(|_| gen.next()).collect();
        let mut t = Table::new(&["precision", "mode", "p50", "p99", "QPS", "items/s"]);
        for precision in ["fp32", "int8"] {
            let server = Arc::new(RecsysServer::new(engine.clone(), batch, precision).unwrap());
            server.infer(&reqs[0]).unwrap(); // warmup
            let mut runs = vec![(
                "pipelined".to_string(),
                server.serve_with(reqs.clone(), &ServeOptions::default()).unwrap(),
            )];
            if threads > 1 {
                runs.push((
                    format!("workers={threads}"),
                    server
                        .serve_with(
                            reqs.clone(),
                            &ServeOptions {
                                workers: threads,
                                pipeline: false,
                                ..ServeOptions::default()
                            },
                        )
                        .unwrap(),
                ));
            }
            for (mode, metrics) in runs {
                t.row(&[
                    precision.to_string(),
                    mode,
                    ms(metrics.latency.p50()),
                    ms(metrics.latency.p99()),
                    format!("{:.1}", metrics.qps()),
                    format!("{:.0}", metrics.items_per_s()),
                ]);
            }
        }
        t.print();

        // micro: single stages, sequential vs sharded-parallel SLS
        let server = Arc::new(RecsysServer::new(engine.clone(), batch, "fp32").unwrap());
        let req = reqs[0].clone();
        let sparse = server.run_sls(&req).unwrap();
        report(&bench_with("sls partition (4 shards, sequential)", 2, 0.4, &mut || {
            server.run_sls(&req).unwrap();
        }));
        if threads > 1 {
            let sharded = Arc::new(
                RecsysServer::with_threads(engine.clone(), batch, "fp32", threads).unwrap(),
            );
            report(&bench_with("sls partition (4 shards, parallel)", 2, 0.4, &mut || {
                sharded.run_sls(&req).unwrap();
            }));
        }
        report(&bench_with("dense partition (fp32)", 2, 0.4, &mut || {
            server.run_dense(&req.dense, &sparse).unwrap();
        }));
    }

    section("E2E: XLM-R bucket-switched serving (real numerics)");
    {
        let server = Arc::new(NlpServer::new(engine.clone()).unwrap());
        let vocab = m.config_usize("xlmr", "vocab").unwrap();
        let mk = || {
            let mut gen = NlpGen::new(1, vocab, 128, 100.0);
            (0..32).map(|_| gen.next()).collect::<Vec<_>>()
        };
        // warmup every bucket
        let _ = server.serve_with(mk(), &ServeOptions::default()).unwrap();
        let mut t = Table::new(&["batching", "workers", "sentences/s", "p50", "pad waste"]);
        for (label, aware) in [("length-aware", true), ("naive", false)] {
            for &w in &thread_points {
                let (metrics, waste) = server
                    .serve_with(
                        mk(),
                        &ServeOptions { length_aware: aware, workers: w, ..ServeOptions::default() },
                    )
                    .unwrap();
                t.row(&[
                    label.to_string(),
                    w.to_string(),
                    format!("{:.1}", metrics.items_per_s()),
                    ms(metrics.latency.p50()),
                    pct(waste),
                ]);
            }
        }
        t.print();
    }

    section("E2E: CV trunk batched serving (real numerics)");
    {
        let server = Arc::new(CvServer::new(engine.clone()).unwrap());
        let mut gen = CvGen::new(1, server.image);
        let mut t = Table::new(&["batch", "workers", "p50", "images/s", "speedup vs b1"]);
        let mut base = 0.0f64;
        for b in server.batch_sizes() {
            let _ = server.serve_with(2, b, &mut gen, &ServeOptions::default()).unwrap(); // warmup
            for &w in &thread_points {
                let metrics = server
                    .serve_with(10, b, &mut gen, &ServeOptions { workers: w, ..ServeOptions::default() })
                    .unwrap();
                if base == 0.0 {
                    base = metrics.items_per_s();
                }
                t.row(&[
                    b.to_string(),
                    w.to_string(),
                    ms(metrics.latency.p50()),
                    format!("{:.1}", metrics.items_per_s()),
                    format!("{:.2}x", metrics.items_per_s() / base),
                ]);
            }
        }
        t.print();
        println!("(paper §VI-B: batch 1->4 gives 1.6-1.8x on the CV concept trunk)");
    }
}
