//! Bench: end-to-end serving over the engine's execution backend (L3 hot
//! path) — the reference interpreter by default, PJRT with `--features
//! pjrt` + built artifacts.
//!
//! Times the actual request path — artifact execution, partition pipeline,
//! batcher — and prints throughput/latency per model family. This is the
//! harness the §Perf optimization loop measures against.
//!
//!     cargo bench --bench e2e_serving

use fbia::runtime::Engine;
use fbia::serving::{CvServer, NlpServer, RecsysServer};
use fbia::util::bench::{bench_with, report, section};
use fbia::util::table::{ms, pct, Table};
use fbia::workloads::{CvGen, NlpGen, RecsysGen};
use std::sync::Arc;

fn main() {
    // cargo runs bench binaries with cwd = rust/; artifacts/ lives at the
    // repository root, one level up
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let engine = Arc::new(Engine::auto(&dir).expect("engine"));
    println!("backend: {}", engine.backend_name());
    let m = engine.manifest().clone();

    section("E2E: DLRM partitioned serving (real numerics)");
    {
        let batch = 32;
        let mut gen = RecsysGen::new(
            1,
            batch,
            m.config_usize("dlrm", "num_tables").unwrap(),
            m.config_usize("dlrm", "rows_per_table").unwrap(),
            m.config_usize("dlrm", "dense_in").unwrap(),
            m.config_usize("dlrm", "max_lookups").unwrap(),
        );
        let reqs: Vec<_> = (0..24).map(|_| gen.next()).collect();
        let mut t = Table::new(&["precision", "p50", "p99", "QPS", "items/s"]);
        for precision in ["fp32", "int8"] {
            let server = Arc::new(RecsysServer::new(engine.clone(), batch, precision).unwrap());
            server.infer(&reqs[0]).unwrap(); // warmup
            let metrics = server.serve(reqs.clone()).unwrap();
            t.row(&[
                precision.to_string(),
                ms(metrics.latency.p50()),
                ms(metrics.latency.p99()),
                format!("{:.1}", metrics.qps()),
                format!("{:.0}", metrics.items_per_s()),
            ]);
        }
        t.print();

        // micro: single stages
        let server = Arc::new(RecsysServer::new(engine.clone(), batch, "fp32").unwrap());
        let req = reqs[0].clone();
        let sparse = server.run_sls(&req).unwrap();
        report(&bench_with("sls partition (4 shards)", 2, 0.4, &mut || {
            server.run_sls(&req).unwrap();
        }));
        report(&bench_with("dense partition (fp32)", 2, 0.4, &mut || {
            server.run_dense(&req.dense, &sparse).unwrap();
        }));
    }

    section("E2E: XLM-R bucket-switched serving (real numerics)");
    {
        let server = NlpServer::new(engine.clone()).unwrap();
        let vocab = m.config_usize("xlmr", "vocab").unwrap();
        let mk = || {
            let mut gen = NlpGen::new(1, vocab, 128, 100.0);
            (0..32).map(|_| gen.next()).collect::<Vec<_>>()
        };
        // warmup every bucket
        let _ = server.serve(mk(), 4, true).unwrap();
        let mut t = Table::new(&["batching", "sentences/s", "p50", "pad waste"]);
        for (label, aware) in [("length-aware", true), ("naive", false)] {
            let (metrics, waste) = server.serve(mk(), 4, aware).unwrap();
            t.row(&[
                label.to_string(),
                format!("{:.1}", metrics.items_per_s()),
                ms(metrics.latency.p50()),
                pct(waste),
            ]);
        }
        t.print();
    }

    section("E2E: CV trunk batched serving (real numerics)");
    {
        let server = CvServer::new(engine.clone()).unwrap();
        let mut gen = CvGen::new(1, server.image);
        let mut t = Table::new(&["batch", "p50", "images/s", "speedup vs b1"]);
        let mut base = 0.0f64;
        for b in server.batch_sizes() {
            let _ = server.serve(2, b, &mut gen).unwrap(); // warmup
            let metrics = server.serve(10, b, &mut gen).unwrap();
            if base == 0.0 {
                base = metrics.items_per_s();
            }
            t.row(&[
                b.to_string(),
                ms(metrics.latency.p50()),
                format!("{:.1}", metrics.items_per_s()),
                format!("{:.2}x", metrics.items_per_s() / base),
            ]);
        }
        t.print();
        println!("(paper §VI-B: batch 1->4 gives 1.6-1.8x on the CV concept trunk)");
    }
}
