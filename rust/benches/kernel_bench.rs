//! Bench: raw kernel speed — the cache-blocked reference kernels (fc,
//! conv2d, sls) at f32 and int8 — plus the zero-allocation property of the
//! prepared reference serving path, proven with a counting allocator.
//!
//!     cargo bench --bench kernel_bench
//!     cargo bench --bench kernel_bench -- --json BENCH_kernels.json
//!
//! The JSON records per-kernel GFLOP/s at both precisions, the int8
//! speedup, and `zero_alloc_*` acceptance flags: after warmup (arena pools
//! converged), N steady-state `RefPrepared::run` calls must perform zero
//! heap allocations.

use fbia::numerics::arena;
use fbia::numerics::ops_ref;
use fbia::numerics::quant::quantize_rowwise_int8;
use fbia::numerics::weights::WeightGen;
use fbia::numerics::HostTensor;
use fbia::runtime::{Engine, Precision, PrepareOptions};
use fbia::serving::WEIGHT_SEED;
use fbia::util::bench::{bench_with, report, section, BenchReport, BenchResult};
use fbia::util::cli::Args;
use fbia::util::json::Json;
use fbia::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

// ---------------------------------------------------------------------------
// Counting allocator: every heap allocation in the process bumps a counter,
// so "zero allocations in steady state" is a measured fact, not a claim.
// ---------------------------------------------------------------------------

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

/// One measured kernel: name, shape label, result, and work per call in
/// floating-point (or int-mac) operations, for the GFLOP/s column.
struct Kernel {
    name: &'static str,
    shape: String,
    flops: f64,
    result: BenchResult,
}

impl Kernel {
    fn gflops(&self) -> f64 {
        self.flops / self.result.mean_s.max(1e-12) / 1e9
    }
}

fn main() {
    let args = Args::from_env(false);

    // deterministic inputs; the RNG seed is fixed so runs are comparable
    let mut rng = Rng::new(7);
    let mut kernels: Vec<Kernel> = Vec::new();

    // -- FC: the DLRM/XLM-R MLP shape (m = batch rows, k = n = d_model) ----
    let (m, k, n) = (32usize, 256usize, 256usize);
    let mut x = vec![0f32; m * k];
    let mut w = vec![0f32; n * k];
    rng.fill_normal_f32(&mut x, 1.0);
    rng.fill_normal_f32(&mut w, 0.05);
    let b = vec![0.01f32; n];
    let mut y = vec![0f32; m * n];
    let fc_flops = 2.0 * (m * k * n) as f64;

    section("FC (blocked, single thread)");
    let r = bench_with("fc f32", 3, 0.3, &mut || {
        ops_ref::fc_into(&x, &w, &b, m, k, n, &mut y);
        std::hint::black_box(&y);
    });
    report(&r);
    kernels.push(Kernel { name: "fc_f32", shape: format!("{m}x{k}x{n}"), flops: fc_flops, result: r });

    let q = quantize_rowwise_int8(&w, n, k);
    let mut xq = Vec::new();
    let r = bench_with("fc int8 (quant_fc)", 3, 0.3, &mut || {
        ops_ref::quant_fc_into(&x, &q.q, &q.scale, &q.zp, &b, m, k, n, &mut xq, &mut y);
        std::hint::black_box(&y);
    });
    report(&r);
    kernels.push(Kernel { name: "fc_int8", shape: format!("{m}x{k}x{n}"), flops: fc_flops, result: r });

    // -- conv2d: a mid-trunk CV block shape --------------------------------
    let (cn, ch, cw, cin, kh, kw, cout) = (1usize, 32usize, 32usize, 32usize, 3usize, 3usize, 32usize);
    let mut cx = vec![0f32; cn * ch * cw * cin];
    let mut cwt = vec![0f32; cout * kh * kw * cin];
    rng.fill_normal_f32(&mut cx, 1.0);
    rng.fill_normal_f32(&mut cwt, 0.05);
    let cb = vec![0.01f32; cout];
    let mut cy = vec![0f32; cn * ch * cw * cout];
    let conv_flops = 2.0 * (cn * ch * cw * cout * kh * kw * cin) as f64;

    section("conv2d (channel-tiled, single thread)");
    let r = bench_with("conv2d f32", 3, 0.3, &mut || {
        ops_ref::conv2d_into(&cx, &cwt, &cb, cn, ch, cw, cin, kh, kw, cout, 1, 1, &mut cy);
        std::hint::black_box(&cy);
    });
    report(&r);
    kernels.push(Kernel {
        name: "conv2d_f32",
        shape: format!("{cn}x{ch}x{cw}x{cin}->{cout} {kh}x{kw}"),
        flops: conv_flops,
        result: r,
    });

    // -- SLS: the DLRM embedding shape (memory-bound; int8 wins on bytes) --
    let (rows, dim, batch, lookups) = (25_000usize, 64usize, 32usize, 32usize);
    let mut table = vec![0f32; rows * dim];
    rng.fill_normal_f32(&mut table, 0.1);
    let indices: Vec<i32> =
        (0..batch * lookups).map(|_| rng.below(rows as u64) as i32).collect();
    let lengths = vec![lookups as i32; batch];
    let mut pooled = vec![0f32; batch * dim];
    // flops = one accumulate per looked-up element
    let sls_flops = (batch * lookups * dim) as f64;

    section("SLS (row streaming)");
    let r = bench_with("sls f32", 3, 0.3, &mut || {
        ops_ref::sls_into(&table, dim, &indices, &lengths, batch, lookups, &mut pooled)
            .expect("sls");
        std::hint::black_box(&pooled);
    });
    report(&r);
    kernels.push(Kernel {
        name: "sls_f32",
        shape: format!("{rows}x{dim} b{batch} L{lookups}"),
        flops: sls_flops,
        result: r,
    });

    let tq = quantize_rowwise_int8(&table, rows, dim);
    let r = bench_with("sls int8 (rowwise q8)", 3, 0.3, &mut || {
        ops_ref::sls_q8_into(
            &tq.q, &tq.scale, &tq.zp, dim, &indices, &lengths, batch, lookups, &mut pooled,
        )
        .expect("sls_q8");
        std::hint::black_box(&pooled);
    });
    report(&r);
    kernels.push(Kernel {
        name: "sls_int8",
        shape: format!("{rows}x{dim} b{batch} L{lookups}"),
        flops: sls_flops,
        result: r,
    });

    let mean = |name: &str| -> f64 {
        kernels.iter().find(|kk| kk.name == name).expect("kernel").result.mean_s
    };
    let fc_speedup = mean("fc_f32") / mean("fc_int8").max(1e-12);
    let sls_speedup = mean("sls_f32") / mean("sls_int8").max(1e-12);

    println!();
    println!("int8 speedup: fc {fc_speedup:.2}x, sls {sls_speedup:.2}x");

    // -- zero-allocation proof on the prepared reference serving path ------
    // prepare once, run many: after warmup the arena pools have converged
    // and N more runs must not touch the heap at all.
    section("zero-alloc steady state (RefPrepared::run, dlrm dense b16)");
    let engine = Engine::builtin();
    let mut zero_alloc = Vec::new();
    for (label, precision) in [("f32", Precision::F32), ("int8", Precision::Int8)] {
        let name = match precision {
            Precision::F32 => "dlrm_dense_b16_fp32",
            Precision::Int8 => "dlrm_dense_b16_int8",
        };
        let weights = WeightGen::new(WEIGHT_SEED).weights_for(engine.manifest().get(name).expect("artifact"));
        let prepared = engine
            .prepare_with(name, weights, PrepareOptions { precision })
            .expect("prepare");
        let mut gen = Rng::new(11);
        let mut dense = vec![0f32; 16 * 256];
        let mut sparse = vec![0f32; 16 * 8 * 64];
        gen.fill_normal_f32(&mut dense, 1.0);
        gen.fill_normal_f32(&mut sparse, 0.1);
        let dense = HostTensor::f32(dense, &[16, 256]);
        let sparse = HostTensor::f32(sparse, &[16, 8, 64]);
        let inputs = [&dense, &sparse];
        // warmup: converge the arena pools (first runs grow them)
        for _ in 0..8 {
            let out = prepared.run_refs(&inputs).expect("warmup run");
            arena::recycle_outputs(out);
        }
        let runs = 64usize;
        let before = allocs();
        for _ in 0..runs {
            let out = prepared.run_refs(&inputs).expect("run");
            arena::recycle_outputs(out);
        }
        let delta = allocs() - before;
        let clean = delta == 0;
        println!(
            "  {label:<5} {runs} steady-state runs -> {delta} heap allocations {}",
            if clean { "(zero-alloc holds)" } else { "(NOT zero-alloc)" }
        );
        zero_alloc.push((label, clean, delta, runs));
    }

    if let Some(path) = args.get("json") {
        let mut bench = BenchReport::new("kernel_bench", "ref", "wall");
        for (label, clean, _, _) in &zero_alloc {
            bench = bench.accept(&format!("zero_alloc_{label}"), *clean);
        }
        bench
            .with(
                "kernels",
                Json::arr(
                    kernels
                        .iter()
                        .map(|kk| {
                            Json::obj(vec![
                                ("name", Json::str(kk.name)),
                                ("shape", Json::str(&kk.shape)),
                                ("mean_us", Json::num(kk.result.mean_s * 1e6)),
                                ("min_us", Json::num(kk.result.min_s * 1e6)),
                                ("gflops", Json::num(kk.gflops())),
                            ])
                        })
                        .collect(),
                ),
            )
            .with(
                "int8_speedup",
                Json::obj(vec![
                    ("fc", Json::num(fc_speedup)),
                    ("sls", Json::num(sls_speedup)),
                ]),
            )
            .with(
                "steady_state_allocs",
                Json::arr(
                    zero_alloc
                        .iter()
                        .map(|(label, _, delta, runs)| {
                            Json::obj(vec![
                                ("precision", Json::str(label)),
                                ("runs", Json::num(*runs as f64)),
                                ("heap_allocations", Json::num(*delta as f64)),
                            ])
                        })
                        .collect(),
                ),
            )
            .write(path)
            .expect("writing bench json");
    }
}
