//! Bench: ablations for every quantified optimization claim in §VI.
//!
//!     cargo bench --bench ablations            # all
//!     cargo bench --bench ablations -- transfers   # one section
//!
//! Sections: parallelization, placement, batching, avgpool, sls_balance,
//! resource_alloc, transfers, netsplit, nlp_int8, buckets, quantization.

use fbia::compiler::parallelize::{parallelize, ParallelPlan};
use fbia::compiler::partition::partition_recsys;
use fbia::compiler::placement::schedule;
use fbia::compiler::{alloc, compile};
use fbia::config::Config;
use fbia::graph::models::{xlmr, DlrmSpec, ModelId, XlmrSpec};
use fbia::graph::ops::OpKind;
use fbia::sim::{simulate_model, simulate_model_batch};
use fbia::util::bench::section;
use fbia::util::table::{f2, ms, pct, Table};

fn want(section_name: &str) -> bool {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    args.is_empty() || args.iter().any(|a| a == section_name)
}

fn main() {
    let cfg = Config::default();

    if want("parallelization") {
        // §VI-B: "we see a 2.6x speedup when parallelizing using this
        // heuristic compared to not doing so" (NLP)
        section("Ablation: op-splitting parallelization (paper: 2.6x on NLP)");
        let g = xlmr(&XlmrSpec::paper(), 1, 32);
        let card = cfg.node.card.clone();
        let nodes: Vec<usize> =
            g.nodes.iter().filter(|n| !n.kind.host_only()).map(|n| n.id).collect();
        let seq = ParallelPlan::sequential(&g, &card);
        let par = parallelize(&g, &card, true);
        let s0 = schedule(&g, &nodes, &seq, &card, card.accel_cores, true);
        let s1 = schedule(&g, &nodes, &par, &card, card.accel_cores, true);
        let mut t = Table::new(&["config", "makespan", "core util", "speedup"]);
        t.row(&["no parallelization".into(), ms(s0.makespan_s), pct(s0.core_utilization), "1.0x".into()]);
        t.row(&[
            "split heuristic".into(),
            ms(s1.makespan_s),
            pct(s1.core_utilization),
            format!("{:.1}x", s0.makespan_s / s1.makespan_s),
        ]);
        t.print();
        println!("paper: 2.6x; measured: {:.1}x", s0.makespan_s / s1.makespan_s);
    }

    if want("placement") {
        // §VI-B: explicit placement gains <= 10-20% for recsys
        section("Ablation: explicit placement hints (paper: <=10-20% gain)");
        let mut t = Table::new(&["model", "vendor default", "with hints", "gain"]);
        for id in [ModelId::RecsysComplex, ModelId::XlmR] {
            let g = id.build();
            let card = cfg.node.card.clone();
            let nodes: Vec<usize> =
                g.nodes.iter().filter(|n| !n.kind.host_only()).map(|n| n.id).collect();
            let par = parallelize(&g, &card, true);
            let off = schedule(&g, &nodes, &par, &card, card.accel_cores, false);
            let on = schedule(&g, &nodes, &par, &card, card.accel_cores, true);
            t.row(&[
                id.name().to_string(),
                ms(off.makespan_s),
                ms(on.makespan_s),
                pct(off.makespan_s / on.makespan_s - 1.0),
            ]);
        }
        t.print();
    }

    if want("batching") {
        // §VI-B: CV batch 1 -> 4 gives 1.6-1.8x
        section("Ablation: CV batching (paper: 1.6-1.8x at batch 4)");
        let mut t = Table::new(&["model", "batch", "latency", "items/s", "speedup vs b1"]);
        for id in [ModelId::ResNeXt101, ModelId::ResNeXt3D] {
            let b1 = simulate_model_batch(id, 1, &cfg, 100).unwrap();
            for b in [1usize, 2, 4, 8] {
                let r = simulate_model_batch(id, b, &cfg, 100).unwrap();
                t.row(&[
                    id.name().to_string(),
                    b.to_string(),
                    ms(r.latency_s),
                    format!("{:.0}", r.items_per_s),
                    format!("{:.2}x", r.items_per_s / b1.items_per_s),
                ]);
            }
        }
        t.print();
    }

    if want("avgpool") {
        // §VI-B: average-pool optimization cut its share from 44% to 6%
        section("Ablation: average-pool kernel optimization (paper: 44% -> 6% of RegNetY)");
        let mk = |optimized: bool| {
            let mut g = ModelId::RegNetY.build();
            for n in g.nodes.iter_mut() {
                if let OpKind::AdaptiveAvgPool { optimized: ref mut o } = n.kind {
                    *o = optimized;
                }
            }
            let c = compile(&g, &cfg).unwrap();
            let breakdown = fbia::sim::op_breakdown(&c);
            breakdown
                .iter()
                .find(|(k, _)| k == "AdaptiveAvgPool")
                .map(|(_, v)| *v)
                .unwrap_or(0.0)
        };
        let before = mk(false);
        let after = mk(true);
        let mut t = Table::new(&["kernel", "AdaptiveAvgPool share of runtime", "paper"]);
        t.row(&["unoptimized".into(), pct(before), "44%".into()]);
        t.row(&["optimized (all pool sizes)".into(), pct(after), "6%".into()]);
        t.print();
    }

    if want("sls_balance") {
        // §VI-B: length-aware SLS balancing cut SLS partition latency 15-34%
        section("Ablation: SLS length-aware load balancing (paper: 15-34% latency cut)");
        // skewed lookup distribution across tables
        let mut spec = DlrmSpec::complex();
        spec.rows_per_table = 10_000_000;
        let mut g = fbia::graph::models::dlrm(&spec, 32);
        for n in g.nodes.iter_mut() {
            if let OpKind::SparseLengthsSum { ref mut avg_lookups } = n.kind {
                let idx: usize = n.name.trim_start_matches("sls").parse().unwrap();
                // hot features cluster at the front of the model definition
                // (typical: the most predictive sparse features come first)
                *avg_lookups = if idx < 8 { 60.0 } else { 15.0 };
            }
        }
        let card = cfg.node.card.clone();
        let par = parallelize(&g, &card, true);
        let mut t = Table::new(&["balancing", "worst-shard SLS makespan", "cut"]);
        let mut results = Vec::new();
        for (label, aware) in [("naive (bytes only)", false), ("length-aware (profiled)", true)] {
            let mut c = cfg.clone();
            c.compiler.sls_length_aware = aware;
            let plan = partition_recsys(&g, &c.compiler, &c.node).unwrap();
            let worst = plan
                .partitions
                .iter()
                .filter(|p| p.kind == fbia::compiler::partition::PartitionKind::Sls)
                .map(|p| schedule(&g, &p.nodes, &par, &card, 4, true).makespan_s)
                .fold(0.0, f64::max);
            results.push((label, worst));
        }
        let base = results[0].1;
        for (label, worst) in &results {
            t.row(&[label.to_string(), ms(*worst), pct(1.0 - worst / base)]);
        }
        t.print();
    }

    if want("resource_alloc") {
        // §VI-B: "generally using 1 in 3 cores for SLS to be a good balance"
        section("Ablation: Accel Core allocation sweep (paper: 1-in-3 for SLS)");
        let g = ModelId::RecsysComplex.build();
        let c = compile(&g, &cfg).unwrap();
        let ppar = parallelize(&c.graph, &cfg.node.card, true);
        if let Some(a) = alloc::sweep_plan(&c.graph, &c.plan, &ppar, &cfg.node.card, true) {
            let mut t = Table::new(&["SLS cores", "dense cores", "SLS time", "dense time", "stage time"]);
            for p in &a.points {
                let mark = if p.sls_cores == a.best.sls_cores { " <- best" } else { "" };
                t.row(&[
                    format!("{}{}", p.sls_cores, mark),
                    p.dense_cores.to_string(),
                    ms(p.sls_time_s),
                    ms(p.dense_time_s),
                    ms(p.stage_time_s),
                ]);
            }
            t.print();
            println!(
                "best: {} of {} cores for SLS ({:.0}%); paper: 1-in-3 (33%)",
                a.best.sls_cores,
                cfg.node.card.accel_cores,
                100.0 * a.best.sls_cores as f64 / cfg.node.card.accel_cores as f64
            );
        }
    }

    if want("transfers") {
        // §VI-C: partial tensors, command batching, P2P (paper: PCIe
        // transfers reduced by over half with P2P)
        section("Ablation: system-level transfer optimizations (§VI-C)");
        let base = simulate_model(ModelId::RecsysComplex, &cfg, 100).unwrap();
        let mut t = Table::new(&[
            "config", "host-link B/req", "p2p B/req", "DMA cmds", "latency", "QPS",
        ]);
        let mut row = |label: &str, c: &Config| {
            let r = simulate_model(ModelId::RecsysComplex, c, 100).unwrap();
            t.row(&[
                label.to_string(),
                format!("{:.0}", r.transfers.host_link_bytes),
                format!("{:.0}", r.transfers.p2p_bytes),
                r.transfers.commands.to_string(),
                ms(r.latency_s),
                format!("{:.0}", r.qps),
            ]);
            r
        };
        row("all optimizations", &cfg);
        let mut c = cfg.clone();
        c.transfers.peer_to_peer = false;
        let no_p2p = row("no P2P (host-mediated)", &c);
        let mut c = cfg.clone();
        c.transfers.partial_tensors = false;
        row("no partial tensors", &c);
        let mut c = cfg.clone();
        c.transfers.command_batching = false;
        row("no command batching", &c);
        let mut c = cfg.clone();
        c.transfers.peer_to_peer = false;
        c.transfers.partial_tensors = false;
        c.transfers.command_batching = false;
        c.transfers.fp16_dense_inputs = false;
        c.transfers.fused_broadcast = false;
        row("none (§VI-C baseline)", &c);
        t.print();
        let cut = 1.0 - base.transfers.host_link_bytes / no_p2p.transfers.host_link_bytes;
        println!(
            "P2P host-link traffic cut: {} (paper: 'reducing PCIe transfers by over half')",
            pct(cut)
        );
    }

    if want("netsplit") {
        // §VI-A: broadcast placement (fused on-card broadcast vs per-table)
        section("Ablation: net split / broadcast placement (§VI-A)");
        let mut t = Table::new(&["broadcast strategy", "upload+overhead time", "latency"]);
        for (label, fused) in [("host concat + single card broadcast", true), ("per-table broadcasts", false)] {
            let mut c = cfg.clone();
            c.transfers.fused_broadcast = fused;
            let r = simulate_model(ModelId::RecsysComplex, &c, 100).unwrap();
            t.row(&[label.to_string(), ms(r.transfers.time_s), ms(r.latency_s)]);
        }
        t.print();
    }

    if want("nlp_int8") {
        // §VII: "we anticipate int8 should yield about 1.6X" for XLM-R
        section("Ablation: XLM-R fp16 vs int8 (paper anticipates ~1.6x)");
        let fp16 = simulate_model(ModelId::XlmR, &cfg, 100).unwrap();
        // int8 variant: quantize the MatMuls (the 72.5% in Table II)
        let mut g = ModelId::XlmR.build();
        let retype: Vec<usize> = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, OpKind::MatMul))
            .flat_map(|n| n.inputs.clone())
            .collect();
        for n in g.nodes.iter_mut() {
            if matches!(n.kind, OpKind::MatMul) {
                n.kind = OpKind::QuantizedFc;
            }
        }
        for t in retype {
            if g.tensors[t].kind == fbia::graph::TensorKind::Weight {
                g.tensors[t].dtype = fbia::graph::DType::I8; // halves weight traffic
            }
        }
        // QuantizedFc expects [x, w, b]: MatMul nodes have [x, w]; the cost
        // model only reads shapes, so reuse as-is for timing purposes.
        let c = compile(&g, &cfg).unwrap();
        let card_time: f64 = c
            .schedules
            .iter()
            .flatten()
            .map(|s| s.makespan_s)
            .sum();
        let fp16_card: f64 = fp16
            .compiled
            .schedules
            .iter()
            .flatten()
            .map(|s| s.makespan_s)
            .sum();
        let mut t = Table::new(&["precision", "card makespan", "speedup"]);
        t.row(&["fp16 (deployed)".into(), ms(fp16_card), "1.0x".into()]);
        t.row(&["int8 (anticipated)".into(), ms(card_time), format!("{:.1}x", fp16_card / card_time)]);
        t.print();
        println!("paper: ~1.6x; measured: {:.1}x", fp16_card / card_time);
    }

    if want("buckets") {
        // §VI-A: multiple compiled networks at padding boundaries vs a
        // single max-length network — the padded-token waste they avoid
        section("Ablation: sequence-length padding buckets (§VI-A)");
        use fbia::serving::batcher::Batcher;
        use fbia::workloads::NlpGen;
        let mut t = Table::new(&["compiled bucket set", "padded tokens", "real tokens", "waste"]);
        for (label, buckets) in [
            ("{512} (single max net)", vec![512usize]),
            ("{128, 512}", vec![128, 512]),
            ("{32, 64, 128, 512} (paper)", vec![32, 64, 128, 512]),
        ] {
            let mut b = Batcher::new(buckets, 8, true);
            let mut gen = NlpGen::new(21, 1000, 512, 100.0);
            for _ in 0..512 {
                b.push(gen.next());
            }
            let batches = b.drain().expect("batcher drain");
            let padded: usize = batches.iter().map(|x| x.padded_tokens()).sum();
            let real: usize = batches.iter().map(|x| x.real_tokens()).sum();
            t.row(&[
                label.to_string(),
                padded.to_string(),
                real.to_string(),
                pct(1.0 - real as f64 / padded.max(1) as f64),
            ]);
        }
        t.print();
        println!("(compute scales with padded tokens: finer buckets ~= proportional savings)");
    }

    if want("quantization") {
        // §V-B/§VI-A: int8 + fp16 dense-feature transfers vs all-fp16
        section("Ablation: quantization on/off (recsys dense + embedding tables)");
        let mut t = Table::new(&["config", "dense makespan", "table GB on node", "fits 6x16 GB"]);
        for (label, q_fc, int4) in [
            ("int8 FC + mixed int4/int8 tables", true, true),
            ("int8 FC + int8 tables", true, false),
            ("fp16 FC + int8 tables", false, false),
        ] {
            let mut spec = DlrmSpec::base();
            spec.quantized_fc = q_fc;
            spec.mixed_int4 = int4;
            let g = fbia::graph::models::dlrm(&spec, 32);
            let gb = g.weight_bytes() as f64 / (1u64 << 30) as f64;
            let fits = g.weight_bytes() <= 6 * cfg.node.card.lpddr_bytes;
            let c = compile(&g, &cfg).unwrap();
            let dense_ms: f64 = c
                .plan
                .partitions
                .iter()
                .zip(&c.schedules)
                .filter(|(p, _)| p.kind == fbia::compiler::partition::PartitionKind::Dense)
                .filter_map(|(_, s)| s.as_ref())
                .map(|s| s.makespan_s)
                .sum();
            t.row(&[
                label.to_string(),
                ms(dense_ms),
                f2(gb),
                if fits { "yes".into() } else { "NO".into() },
            ]);
        }
        t.print();
        println!("(fp16 tables would need ~2 B/param: the 70 B-param model would not fit the node at all — the paper's motivation for int8/int4 embeddings, §V-B)");
    }
}
