//! Bench: regenerate **Figure 7** — latency and relative QPS of the complex
//! models on the accelerator node, against their latency bands — plus the
//! real DLRM serving path at 1 vs N threads, so the perf trajectory records
//! the intra-host threading speedup.
//!
//!     cargo bench --bench fig7_latency_qps
//!     cargo bench --bench fig7_latency_qps -- --json BENCH_smoke.json \
//!         [--threads 4] [--serve-requests 24] [--backend sim]
//!
//! `--json <path>` additionally writes a machine-readable summary (the CI
//! smoke artifact), including the `dlrm_serving` thread-scaling points,
//! the `dlrm_precision` within-run f32-vs-int8 QPS comparison (acceptance
//! flag `int8_2x_dlrm_qps`), and the `xlmr_serving` throughput record.
//! With `--backend sim` the serving section runs the same numerics on the
//! modeled card clock and the JSON records card-accurate latency checked
//! against the DLRM latency budget (the `BENCH_sim_smoke.json` artifact).

use fbia::config::Config;
use fbia::graph::models::ModelId;
use fbia::runtime::{Clock, Engine};
use fbia::serving::{NlpServer, RecsysServer, ServeOptions};
use fbia::sim::simulate_model;
use fbia::util::bench::{section, BenchReport};
use fbia::util::cli::Args;
use fbia::util::json::Json;
use fbia::util::table::{ms, pct, Table};
use fbia::workloads::{NlpGen, RecsysGen};
use std::sync::Arc;

/// Serve the same request set at each thread count on the selected
/// execution backend; returns the backend that actually ran, its clock,
/// and (threads, qps, p50_s, p99_s) points, 1-thread first.
fn dlrm_thread_scaling(
    threads: usize,
    requests: usize,
    backend: Option<&str>,
) -> (&'static str, Clock, Vec<(usize, f64, f64, f64)>) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let engine = Arc::new(Engine::auto_with(&dir, backend).expect("engine"));
    let backend_name = engine.backend_name();
    let clock = engine.clock();
    let batch = 32;
    let mut gen = RecsysGen::from_manifest(1, batch, engine.manifest()).expect("gen");
    let server = Arc::new(RecsysServer::new(engine, batch, "int8").expect("server"));
    let reqs: Vec<_> = (0..requests).map(|_| gen.next()).collect();
    server.infer(&reqs[0]).expect("warmup");
    let mut points = Vec::new();
    for t in [1, threads] {
        // `pipeline: false` keeps t=1 on the strictly sequential baseline
        // the thread-scaling speedup is measured against
        let metrics = server
            .serve_with(
                reqs.clone(),
                &ServeOptions { workers: t, pipeline: false, ..ServeOptions::default() },
            )
            .expect("serve");
        points.push((t, metrics.qps(), metrics.latency.p50(), metrics.latency.p99()));
        if threads <= 1 {
            break;
        }
    }
    (backend_name, clock, points)
}

/// Serve the same request set on an f32-prepared and an int8-prepared
/// server (same engine, same clock, 1 worker, no pipelining): the
/// within-run precision comparison the int8 deployment is justified by.
/// Returns (f32_qps, int8_qps).
fn dlrm_precision_qps(requests: usize, backend: Option<&str>) -> (f64, f64) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let engine = Arc::new(Engine::auto_with(&dir, backend).expect("engine"));
    let batch = 32;
    let mut gen = RecsysGen::from_manifest(2, batch, engine.manifest()).expect("gen");
    let reqs: Vec<_> = (0..requests).map(|_| gen.next()).collect();
    let opts = ServeOptions { workers: 1, pipeline: false, ..ServeOptions::default() };
    let mut qps = [0f64; 2];
    for (i, prec) in ["fp32", "int8"].iter().enumerate() {
        let server = Arc::new(RecsysServer::new(engine.clone(), batch, prec).expect("server"));
        server.infer(&reqs[0]).expect("warmup");
        qps[i] = server.serve_with(reqs.clone(), &opts).expect("serve").qps();
    }
    (qps[0], qps[1])
}

/// XLM-R closed-loop throughput (sentences/s) on the same backend, so the
/// smoke artifact tracks both model families' serving trajectories.
fn xlmr_qps(requests: usize, backend: Option<&str>) -> f64 {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let engine = Arc::new(Engine::auto_with(&dir, backend).expect("engine"));
    let vocab = engine.manifest().config_usize("xlmr", "vocab").expect("xlmr vocab");
    let mk = || {
        let mut gen = NlpGen::new(3, vocab, 64, 100.0);
        (0..requests).map(|_| gen.next()).collect::<Vec<_>>()
    };
    let server = Arc::new(NlpServer::new(engine).expect("nlp server"));
    let _ = server.serve_with(mk(), &ServeOptions::default()).expect("warmup");
    let (metrics, _) = server.serve_with(mk(), &ServeOptions::default()).expect("serve");
    metrics.items_per_s()
}

fn main() {
    let args = Args::from_env(false);
    let cfg = Config::default();
    section("Figure 7: latency and relative QPS per model (simulated node)");

    // QPS normalized to the slowest model, like the paper's "relative QPS"
    let mut rows = Vec::new();
    for id in ModelId::ALL {
        let r = simulate_model(id, &cfg, 400).expect("simulate");
        rows.push((id, r));
    }
    let min_qps = rows.iter().map(|(_, r)| r.qps).fold(f64::INFINITY, f64::min);

    let mut t = Table::new(&[
        "model", "batch", "latency", "band", "within band", "relative QPS", "core util",
    ]);
    for (id, r) in &rows {
        t.row(&[
            id.name().to_string(),
            r.batch.to_string(),
            ms(r.latency_s),
            format!("<= {}", ms(id.latency_budget_s())),
            if r.meets_budget { "yes".into() } else { "NO".into() },
            format!("{:.1}x", r.qps / min_qps),
            pct(r.core_utilization),
        ]);
    }
    t.print();

    // the paper's headline observations, checked mechanically:
    let rec = rows.iter().find(|(id, _)| *id == ModelId::RecsysComplex).unwrap();
    let cu_max = rows
        .iter()
        .filter(|(id, _)| !matches!(id, ModelId::RecsysBase | ModelId::RecsysComplex))
        .map(|(_, r)| r.latency_s)
        .fold(0.0, f64::max);
    println!();
    println!(
        "paper: 'recommendation models run at much lower latency and higher QPS per batch'\n  -> recsys {} vs slowest CU model {} : {}",
        ms(rec.1.latency_s),
        ms(cu_max),
        if rec.1.latency_s < cu_max { "holds" } else { "VIOLATED" }
    );
    let all_meet = rows.iter().all(|(_, r)| r.meets_budget);
    println!(
        "paper: 'the accelerator is able to serve all of these complex models within the latency budget' -> {}",
        if all_meet { "holds" } else { "VIOLATED" }
    );

    // real serving path: same requests at 1 thread vs N threads, on the
    // selected backend (`--backend sim` -> modeled card clock)
    let threads = args.get_usize("threads", 4).max(1);
    let serve_requests = args.get_usize("serve-requests", 24).max(1);
    let backend = args.get("backend");
    section("DLRM serving thread-scaling (real numerics, batch 32 int8)");
    let (backend_name, clock, points) = dlrm_thread_scaling(threads, serve_requests, backend);
    let base_qps = points[0].1;
    let mut ts = Table::new(&["threads", "QPS", "p50", "speedup"]);
    for &(t, qps, p50, _) in &points {
        ts.row(&[
            t.to_string(),
            format!("{qps:.1}"),
            ms(p50),
            format!("{:.2}x", qps / base_qps),
        ]);
    }
    ts.print();
    // precision comparison: the same requests served f32 then int8 on the
    // same engine — the within-run measurement behind the ">= 2x DLRM QPS
    // from int8" deployment claim (meaningful on the wall clock; on the
    // modeled clock it reports the card model's own int8 delta)
    section("DLRM serving precision (same requests, f32 vs int8, 1 worker)");
    let (f32_qps, int8_qps) = dlrm_precision_qps(serve_requests, backend);
    let int8_speedup = int8_qps / f32_qps.max(1e-12);
    let mut pt = Table::new(&["precision", "QPS", "speedup"]);
    pt.row(&["f32".into(), format!("{f32_qps:.1}"), "1.00x".into()]);
    pt.row(&["int8".into(), format!("{int8_qps:.1}"), format!("{int8_speedup:.2}x")]);
    pt.print();
    println!(
        "int8 vs f32 within-run: {:.2}x -> {}",
        int8_speedup,
        if int8_speedup >= 2.0 { "meets the 2x bar" } else { "BELOW the 2x bar" }
    );

    section("XLM-R closed-loop throughput");
    let xlmr_sentences_s = xlmr_qps(serve_requests, backend);
    println!("xlmr: {xlmr_sentences_s:.1} sentences/s");

    let dlrm_budget_s = ModelId::RecsysComplex.latency_budget_s();
    if clock == Clock::Modeled {
        let p50 = points[0].2;
        println!(
            "modeled card latency: p50 {} vs budget {} -> {}",
            ms(p50),
            ms(dlrm_budget_s),
            if p50 <= dlrm_budget_s { "within budget" } else { "EXCEEDS BUDGET" }
        );
    }

    if let Some(path) = args.get("json") {
        // shared BENCH_*.json schema: headline from the serving section
        // (full-thread throughput, 1-thread budget-gated p50), figure rows
        // and thread-scaling points as detail
        let p50_1thread = points[0].2;
        let &(_, last_qps, _, last_p99) = points.last().expect("at least one point");
        let mut bench = BenchReport::new("fig7_latency_qps", backend_name, clock.name());
        bench.offered = serve_requests;
        bench.completed = serve_requests;
        bench.qps = last_qps;
        bench.p50_ms = p50_1thread * 1e3;
        bench.p99_ms = last_p99 * 1e3;
        bench
            .accept("all_within_budget", all_meet)
            .accept(
                "p50_within_budget",
                clock != Clock::Modeled || p50_1thread <= dlrm_budget_s,
            )
            .accept("int8_2x_dlrm_qps", int8_speedup >= 2.0)
            .with(
                "dlrm_precision",
                Json::obj(vec![
                    ("f32_qps", Json::num(f32_qps)),
                    ("int8_qps", Json::num(int8_qps)),
                    ("int8_speedup", Json::num(int8_speedup)),
                    ("batch", Json::num(32.0)),
                    ("requests", Json::num(serve_requests as f64)),
                ]),
            )
            .with(
                "xlmr_serving",
                Json::obj(vec![
                    ("sentences_per_s", Json::num(xlmr_sentences_s)),
                    ("requests", Json::num(serve_requests as f64)),
                ]),
            )
            .with(
                "dlrm_serving",
                Json::obj(vec![
                    ("backend", Json::str(backend_name)),
                    ("clock", Json::str(clock.name())),
                    ("modeled", Json::Bool(clock == Clock::Modeled)),
                    ("latency_budget_ms", Json::num(dlrm_budget_s * 1e3)),
                    (
                        "p50_within_budget",
                        Json::Bool(clock != Clock::Modeled || p50_1thread <= dlrm_budget_s),
                    ),
                    ("batch", Json::num(32.0)),
                    ("requests", Json::num(serve_requests as f64)),
                    (
                        "points",
                        Json::arr(
                            points
                                .iter()
                                .map(|&(t, qps, p50, p99)| {
                                    Json::obj(vec![
                                        ("threads", Json::num(t as f64)),
                                        ("qps", Json::num(qps)),
                                        ("p50_ms", Json::num(p50 * 1e3)),
                                        ("p99_ms", Json::num(p99 * 1e3)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "speedup",
                        Json::num(points.last().map(|p| p.1 / base_qps).unwrap_or(1.0)),
                    ),
                ]),
            )
            .with(
                "rows",
                Json::arr(
                    rows.iter()
                        .map(|(id, r)| {
                            Json::obj(vec![
                                ("model", Json::str(id.name())),
                                ("batch", Json::num(r.batch as f64)),
                                ("latency_ms", Json::num(r.latency_s * 1e3)),
                                ("budget_ms", Json::num(id.latency_budget_s() * 1e3)),
                                ("meets_budget", Json::Bool(r.meets_budget)),
                                ("qps", Json::num(r.qps)),
                                ("relative_qps", Json::num(r.qps / min_qps)),
                                ("core_utilization", Json::num(r.core_utilization)),
                            ])
                        })
                        .collect(),
                ),
            )
            .write(path)
            .expect("writing bench json");
    }
}
