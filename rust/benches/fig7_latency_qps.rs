//! Bench: regenerate **Figure 7** — latency and relative QPS of the complex
//! models on the accelerator node, against their latency bands.
//!
//!     cargo bench --bench fig7_latency_qps
//!     cargo bench --bench fig7_latency_qps -- --json BENCH_smoke.json
//!
//! `--json <path>` additionally writes a machine-readable summary (the CI
//! smoke artifact).

use fbia::config::Config;
use fbia::graph::models::ModelId;
use fbia::sim::simulate_model;
use fbia::util::bench::section;
use fbia::util::cli::Args;
use fbia::util::json::Json;
use fbia::util::table::{ms, pct, Table};

fn main() {
    let args = Args::from_env(false);
    let cfg = Config::default();
    section("Figure 7: latency and relative QPS per model (simulated node)");

    // QPS normalized to the slowest model, like the paper's "relative QPS"
    let mut rows = Vec::new();
    for id in ModelId::ALL {
        let r = simulate_model(id, &cfg, 400).expect("simulate");
        rows.push((id, r));
    }
    let min_qps = rows.iter().map(|(_, r)| r.qps).fold(f64::INFINITY, f64::min);

    let mut t = Table::new(&[
        "model", "batch", "latency", "band", "within band", "relative QPS", "core util",
    ]);
    for (id, r) in &rows {
        t.row(&[
            id.name().to_string(),
            r.batch.to_string(),
            ms(r.latency_s),
            format!("<= {}", ms(id.latency_budget_s())),
            if r.meets_budget { "yes".into() } else { "NO".into() },
            format!("{:.1}x", r.qps / min_qps),
            pct(r.core_utilization),
        ]);
    }
    t.print();

    // the paper's headline observations, checked mechanically:
    let rec = rows.iter().find(|(id, _)| *id == ModelId::RecsysComplex).unwrap();
    let cu_max = rows
        .iter()
        .filter(|(id, _)| !matches!(id, ModelId::RecsysBase | ModelId::RecsysComplex))
        .map(|(_, r)| r.latency_s)
        .fold(0.0, f64::max);
    println!();
    println!(
        "paper: 'recommendation models run at much lower latency and higher QPS per batch'\n  -> recsys {} vs slowest CU model {} : {}",
        ms(rec.1.latency_s),
        ms(cu_max),
        if rec.1.latency_s < cu_max { "holds" } else { "VIOLATED" }
    );
    let all_meet = rows.iter().all(|(_, r)| r.meets_budget);
    println!(
        "paper: 'the accelerator is able to serve all of these complex models within the latency budget' -> {}",
        if all_meet { "holds" } else { "VIOLATED" }
    );

    if let Some(path) = args.get("json") {
        let json = Json::obj(vec![
            ("bench", Json::str("fig7_latency_qps")),
            ("all_within_budget", Json::Bool(all_meet)),
            (
                "rows",
                Json::arr(
                    rows.iter()
                        .map(|(id, r)| {
                            Json::obj(vec![
                                ("model", Json::str(id.name())),
                                ("batch", Json::num(r.batch as f64)),
                                ("latency_ms", Json::num(r.latency_s * 1e3)),
                                ("budget_ms", Json::num(id.latency_budget_s() * 1e3)),
                                ("meets_budget", Json::Bool(r.meets_budget)),
                                ("qps", Json::num(r.qps)),
                                ("relative_qps", Json::num(r.qps / min_qps)),
                                ("core_utilization", Json::num(r.core_utilization)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(path, json.to_string()).expect("writing bench json");
        println!("wrote {path}");
    }
}
