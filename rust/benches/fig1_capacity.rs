//! Bench: regenerate **Figure 1** — growth in the number of inference
//! servers for (a) recommendation and (b) other ML over 8 quarters.
//!
//!     cargo bench --bench fig1_capacity

use fbia::capacity::{capacity_series, power_savings, GrowthScenario};
use fbia::config::Config;
use fbia::graph::models::ModelId;
use fbia::util::bench::section;
use fbia::util::table::{f2, Table};

fn main() {
    let cfg = Config::default();
    for (scenario, model, label) in [
        (GrowthScenario::recommendation(), ModelId::RecsysComplex, "Fig. 1a: recommendation"),
        (GrowthScenario::other_ml(), ModelId::XlmR, "Fig. 1b: other ML (CV/text)"),
    ] {
        section(label);
        let pts = capacity_series(model, &scenario, &cfg).expect("capacity");
        let mut t = Table::new(&[
            "quarter", "demand QPS", "servers (CPU fleet)", "servers (accel fleet)", "growth vs t0",
        ]);
        for p in &pts {
            t.row(&[
                p.quarter.to_string(),
                format!("{:.0}", p.demand_qps),
                format!("{:.0}", p.cpu_servers),
                format!("{:.0}", p.accel_servers),
                f2(p.cpu_norm),
            ]);
        }
        t.print();
        let last = pts.last().unwrap();
        let ok = last.cpu_norm >= 4.5 && last.cpu_norm <= 7.5;
        println!(
            "growth over window: {:.1}x (paper: 5-7x) -> {}",
            last.cpu_norm,
            if ok { "within band" } else { "OUT OF BAND" }
        );
        println!("power saved at final quarter: {:.1} kW", power_savings(&pts, &cfg) / 1e3);
    }
}
