//! Bench: cluster-tier scaling — node counts x node policies, and the
//! NIC-bound regime (Fig. 1 at datacenter scale, §VII).
//!
//!     cargo bench --bench cluster_scale
//!     cargo bench --bench cluster_scale -- --requests 200 --mix 70/20/10 \
//!         [--json BENCH_cluster_scale.json]
//!
//! Routes (never executes) a deterministic mixed burst through tiers of
//! 1/2/4 nodes under every node policy, then sweeps the NIC line rate on a
//! fixed tier to show cluster throughput pinned by `NicSpec.bw_bits` while
//! the cards' modeled costs stay untouched. Bit-reproducible: same flags,
//! same numbers.

use fbia::config::Config;
use fbia::serving::cluster::{Cluster, NodePolicy, Scenario};
use fbia::serving::fleet::{Arrival, FamilyMix, FleetConfig, RoutePolicy, TrafficGen};
use fbia::util::bench::section;
use fbia::util::cli::Args;
use fbia::util::json::Json;
use fbia::util::table::{ms, Table};
use std::sync::Arc;

fn main() {
    let args = Args::from_env(false);
    let requests = args.get_usize("requests", 150).max(1);
    let mix = FamilyMix::parse(args.get_or("mix", "70/20/10")).expect("mix");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    let cfg = Config::default();
    let fcfg = FleetConfig { replicas: 2, ..FleetConfig::default() };
    let card_policy = RoutePolicy::LatencyAware;

    section("Cluster tier: node count x node policy (modeled clock, burst)");
    let mut rows = Vec::new();
    let mut t = Table::new(&["nodes", "node policy", "completed", "cluster QPS", "p50", "p99"]);
    for nodes in [1usize, 2, 4] {
        let specs = vec![cfg.node.clone(); nodes];
        let cluster =
            Arc::new(Cluster::new(&dir, &cfg, &specs, fcfg.clone()).expect("cluster"));
        let mut traffic =
            TrafficGen::new(1, mix, Arrival::Burst, cluster.manifest(), fcfg.recsys_batch)
                .expect("traffic");
        let reqs = traffic.take(requests);
        for policy in NodePolicy::ALL {
            let m = cluster
                .route(&reqs, policy, card_policy, &Scenario::none())
                .expect("route");
            t.row(&[
                nodes.to_string(),
                policy.name().to_string(),
                m.cluster.completed.to_string(),
                format!("{:.1}", m.cluster_qps()),
                ms(m.cluster.latency.p50()),
                ms(m.cluster.latency.p99()),
            ]);
            rows.push((nodes, policy, m.cluster_qps(), m.shed_rate()));
        }
    }
    t.print();

    // more nodes must buy throughput under the capacity-aware policy
    let qps_of = |n: usize| {
        rows.iter()
            .find(|(k, p, _, _)| *k == n && *p == NodePolicy::WeightedCapacity)
            .map(|(_, _, q, _)| *q)
            .unwrap()
    };
    println!();
    println!(
        "scaling (weighted): 1 node {:.1} -> 2 nodes {:.1} -> 4 nodes {:.1} QPS -> {}",
        qps_of(1),
        qps_of(2),
        qps_of(4),
        if qps_of(2) > qps_of(1) && qps_of(4) > qps_of(2) { "holds" } else { "VIOLATED" }
    );

    section("NIC-bound regime: cluster QPS vs NIC line rate (2 nodes)");
    let mut nic_rows = Vec::new();
    let mut tn = Table::new(&["NIC bw (Mbit/s)", "cluster QPS", "p99"]);
    for bw_mbit in [400.0f64, 200.0, 100.0] {
        let mut node = cfg.node.clone();
        node.nic.bw_bits = bw_mbit * 1e6;
        let specs = vec![node; 2];
        let cluster =
            Arc::new(Cluster::new(&dir, &cfg, &specs, fcfg.clone()).expect("cluster"));
        let mut traffic =
            TrafficGen::new(1, mix, Arrival::Burst, cluster.manifest(), fcfg.recsys_batch)
                .expect("traffic");
        let reqs = traffic.take(requests);
        let m = cluster
            .route(&reqs, NodePolicy::WeightedCapacity, card_policy, &Scenario::none())
            .expect("route");
        tn.row(&[
            format!("{bw_mbit:.0}"),
            format!("{:.1}", m.cluster_qps()),
            ms(m.cluster.latency.p99()),
        ]);
        nic_rows.push((bw_mbit, m.cluster_qps()));
    }
    tn.print();
    println!(
        "NIC gates throughput: {:.1} -> {:.1} -> {:.1} QPS as the line rate halves -> {}",
        nic_rows[0].1,
        nic_rows[1].1,
        nic_rows[2].1,
        if nic_rows[0].1 > nic_rows[1].1 && nic_rows[1].1 > nic_rows[2].1 {
            "holds"
        } else {
            "VIOLATED"
        }
    );

    if let Some(path) = args.get("json") {
        let json = Json::obj(vec![
            ("bench", Json::str("cluster_scale")),
            ("mix", Json::str(&mix.label())),
            ("requests", Json::num(requests as f64)),
            (
                "scaling",
                Json::arr(
                    rows.iter()
                        .map(|(n, p, q, s)| {
                            Json::obj(vec![
                                ("nodes", Json::num(*n as f64)),
                                ("policy", Json::str(p.name())),
                                ("cluster_qps", Json::num(*q)),
                                ("shed_rate", Json::num(*s)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "nic_sweep",
                Json::arr(
                    nic_rows
                        .iter()
                        .map(|(bw, q)| {
                            Json::obj(vec![
                                ("bw_mbit", Json::num(*bw)),
                                ("cluster_qps", Json::num(*q)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(path, json.to_string()).expect("writing bench json");
        println!("wrote {path}");
    }
}
