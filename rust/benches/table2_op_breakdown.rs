//! Bench: regenerate **Table II** — per-op runtime breakdown for each model,
//! side by side with the paper's reported shares.
//!
//!     cargo bench --bench table2_op_breakdown

use fbia::config::Config;
use fbia::graph::models::ModelId;
use fbia::sim::simulate_model;
use fbia::util::bench::section;
use fbia::util::table::{pct, Table};

/// Paper Table II values (top rows per model).
fn paper_shares(id: ModelId) -> &'static [(&'static str, f64)] {
    match id {
        ModelId::RecsysBase | ModelId::RecsysComplex => &[
            ("FC", 0.309),
            ("SLS", 0.270),
            ("BatchMatMul", 0.088),
            ("Quantize", 0.048),
            ("Transpose", 0.043),
            ("Dequantize", 0.036),
        ],
        ModelId::ResNeXt101 => &[
            ("ChannelwiseQuantizedConv", 0.573),
            ("Add", 0.374),
            ("ConvertTo", 0.025),
            ("Quantize", 0.006),
            ("AdaptiveAvgPool", 0.002),
        ],
        ModelId::FbNetV3 => &[
            ("ChannelwiseQuantizedConv", 0.670),
            ("Fused Conv_Add", 0.272),
            ("ROIAlign", 0.027),
            ("ConvertTo", 0.007),
            ("Quantize", 0.005),
        ],
        ModelId::RegNetY => &[
            ("ChannelwiseQuantizedConv", 0.681),
            ("Tile", 0.137),
            ("AdaptiveAvgPool", 0.060),
            ("Add", 0.060),
            ("Mul", 0.044),
        ],
        ModelId::ResNeXt3D => &[
            ("Convolution3D", 0.184),
            ("MatMul", 0.133),
            ("Convolution", 0.102),
            ("Add", 0.065),
            ("Transpose", 0.065),
            ("MaxPool", 0.061),
        ],
        ModelId::XlmR => &[
            ("MatMul", 0.725),
            ("Transpose", 0.036),
            ("Softmax", 0.033),
            ("Add", 0.030),
            ("Gelu", 0.022),
            ("Concat", 0.021),
        ],
    }
}

fn main() {
    let cfg = Config::default();
    section("Table II: op-level runtime breakdown (simulated vs paper)");

    for id in ModelId::ALL {
        let r = simulate_model(id, &cfg, 20).expect("simulate");
        println!("\n--- {} ---", id.name());
        let paper = paper_shares(id);
        let mut t = Table::new(&["op (measured)", "share", "", "op (paper)", "share"]);
        let n = r.op_breakdown.len().max(paper.len());
        for i in 0..n.min(8) {
            let (mk, mv) = r
                .op_breakdown
                .get(i)
                .map(|(k, v)| (k.clone(), pct(*v)))
                .unwrap_or_default();
            let (pk, pv) = paper
                .get(i)
                .map(|(k, v)| (k.to_string(), pct(*v)))
                .unwrap_or_default();
            t.row(&[mk, mv, "|".into(), pk, pv]);
        }
        t.print();
        // shape check: does our top op match the paper's top op family?
        if let (Some((mk, _)), Some((pk, _))) = (r.op_breakdown.first(), paper.first()) {
            let fam = |s: &str| {
                if s.contains("Conv") {
                    "Conv"
                } else if s == "FC" || s == "SLS" {
                    "FC/SLS"
                } else {
                    "other"
                }
            };
            let ok = mk == pk || fam(mk) == fam(pk);
            println!("top-op agreement: measured '{mk}' vs paper '{pk}' -> {}", if ok { "match" } else { "DIFFERS" });
        }
    }
}
