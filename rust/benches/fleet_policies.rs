//! Bench: fleet routing policies × replica placements on the modeled
//! clock — the §IV/§VI-B multi-card scheduling study.
//!
//!     cargo bench --bench fleet_policies
//!     cargo bench --bench fleet_policies -- --requests 200 --mix 60/30/10 \
//!         [--json BENCH_fleet_policies.json]
//!
//! Routes (never executes) a deterministic mixed trace through every
//! (placement, policy) pair and reports modeled node QPS, shed rate and
//! tail latency. Everything here is bit-reproducible: same flags, same
//! numbers.

use fbia::runtime::Engine;
use fbia::serving::fleet::{
    Arrival, FamilyMix, Fleet, FleetConfig, Placement, RoutePolicy, TrafficGen,
};
use fbia::util::bench::section;
use fbia::util::cli::Args;
use fbia::util::json::Json;
use fbia::util::table::{ms, pct, Table};
use std::sync::Arc;

fn main() {
    let args = Args::from_env(false);
    let requests = args.get_usize("requests", 150).max(1);
    let mix = FamilyMix::parse(args.get_or("mix", "70/20/10")).expect("mix");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");

    section("Fleet routing: policy x placement on the modeled clock");
    let mut rows = Vec::new();
    let mut t = Table::new(&[
        "placement", "policy", "admitted", "shed%", "node QPS", "p50", "p99",
    ]);
    for placement in Placement::ALL {
        let engine =
            Arc::new(Engine::auto_with(&dir, Some("sim")).expect("sim engine"));
        let cfg = FleetConfig { placement, ..FleetConfig::default() };
        let fleet = Fleet::new(engine.clone(), cfg.clone()).expect("fleet");
        let mut traffic =
            TrafficGen::new(1, mix, Arrival::Burst, engine.manifest(), cfg.recsys_batch)
                .expect("traffic");
        let reqs = traffic.take(requests);
        for policy in RoutePolicy::ALL {
            let m = fleet.route(&reqs, policy).expect("route");
            t.row(&[
                placement.name().to_string(),
                policy.name().to_string(),
                m.node.completed.to_string(),
                pct(m.shed_rate()),
                format!("{:.1}", m.node_qps()),
                ms(m.node.latency.p50()),
                ms(m.node.latency.p99()),
            ]);
            rows.push((placement, policy, m));
        }
    }
    t.print();

    // headline checks the router exists for
    let find = |pl: Placement, po: RoutePolicy| {
        rows.iter().find(|(a, b, _)| *a == pl && *b == po).map(|(_, _, m)| m).unwrap()
    };
    let rr = find(Placement::SlsAffine, RoutePolicy::RoundRobin);
    let la = find(Placement::SlsAffine, RoutePolicy::LatencyAware);
    println!();
    println!(
        "latency-aware vs round-robin (sls-affine): {:.1} vs {:.1} node QPS -> {}",
        la.node_qps(),
        rr.node_qps(),
        if la.node_qps() > rr.node_qps() && la.shed_rate() <= rr.shed_rate() {
            "holds"
        } else {
            "VIOLATED"
        }
    );
    let pack = find(Placement::Pack, RoutePolicy::LatencyAware);
    println!(
        "spreading beats packing: sls-affine {:.1} vs pack {:.1} node QPS -> {}",
        la.node_qps(),
        pack.node_qps(),
        if la.node_qps() > pack.node_qps() { "holds" } else { "VIOLATED" }
    );

    if let Some(path) = args.get("json") {
        let json = Json::obj(vec![
            ("bench", Json::str("fleet_policies")),
            ("mix", Json::str(&mix.label())),
            ("requests", Json::num(requests as f64)),
            (
                "rows",
                Json::arr(
                    rows.iter()
                        .map(|(pl, po, m)| {
                            Json::obj(vec![
                                ("placement", Json::str(pl.name())),
                                ("policy", Json::str(po.name())),
                                ("node_qps", Json::num(m.node_qps())),
                                ("shed_rate", Json::num(m.shed_rate())),
                                ("p50_ms", Json::num(m.node.latency.p50() * 1e3)),
                                ("p99_ms", Json::num(m.node.latency.p99() * 1e3)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(path, json.to_string()).expect("writing bench json");
        println!("wrote {path}");
    }
}
