//! Bench: regenerate **Table I** — model characteristics (params, FLOPs,
//! arithmetic intensity, latency constraints) from the graph builders, side
//! by side with the paper's numbers.
//!
//!     cargo bench --bench table1_characteristics

use fbia::graph::models::ModelId;
use fbia::util::bench::section;
use fbia::util::table::Table;

/// Paper Table I values: (MParams, GFLOPs/batch, arith. intensity).
fn paper(id: ModelId) -> (f64, f64, f64) {
    match id {
        ModelId::RecsysBase => (70_000.0, 0.02, 90.0),
        ModelId::RecsysComplex => (100_000.0, 0.1, 80.0),
        ModelId::ResNeXt101 => (44.0, 15.6, 355.0),
        ModelId::RegNetY => (700.0, 256.0, 395.0),
        ModelId::FbNetV3 => (28.6, 72.0, 1946.0),
        ModelId::ResNeXt3D => (58.0, 3.4, 362.0),
        ModelId::XlmR => (558.0, 20.0, 45.0), // AI = #tokens (20-70)
    }
}

fn main() {
    section("Table I: model characteristics (built graphs vs paper)");
    let mut t = Table::new(&[
        "model", "MParams", "paper", "GFLOPs/batch", "paper", "arith. int.", "paper", "latency bound",
    ]);
    for id in ModelId::ALL {
        let g = id.build();
        g.validate().expect("valid graph");
        let (pp, pf, pa) = paper(id);
        t.row(&[
            id.name().to_string(),
            format!("{:.1}", g.param_count() as f64 / 1e6),
            format!("{pp:.1}"),
            format!("{:.2}", g.total_flops() / 1e9),
            format!("{pf:.2}"),
            format!("{:.0}", g.arithmetic_intensity()),
            format!("{pa:.0}"),
            format!("{:.0} ms", id.latency_budget_s() * 1e3),
        ]);
    }
    t.print();

    println!();
    println!("ordering checks (the shape the table must preserve):");
    let ai = |id: ModelId| id.build().arithmetic_intensity();
    let checks: Vec<(&str, bool)> = vec![
        (
            "CV models have much higher arithmetic intensity than recsys/NLP",
            ai(ModelId::ResNeXt101) > 3.0 * ai(ModelId::XlmR)
                && ai(ModelId::ResNeXt101) > 3.0 * ai(ModelId::RecsysBase),
        ),
        (
            "RegNetY ~15x ResNeXt101 in params & FLOPs",
            {
                let a = ModelId::ResNeXt101.build();
                let b = ModelId::RegNetY.build();
                b.param_count() > 8 * a.param_count() && b.total_flops() > 8.0 * a.total_flops()
            },
        ),
        (
            "recsys params dwarf everything (embedding tables)",
            ModelId::RecsysBase.build().param_count() > 50_000_000_000,
        ),
        (
            "complex recsys ~5x base GFLOPs",
            {
                let r = ModelId::RecsysComplex.build().total_flops()
                    / ModelId::RecsysBase.build().total_flops();
                (2.5..12.0).contains(&r)
            },
        ),
    ];
    for (name, ok) in checks {
        println!("  [{}] {}", if ok { "ok" } else { "FAIL" }, name);
    }
}
