//! API-compatible stub of the `xla` crate (xla-rs 0.1.6) for offline builds.
//!
//! The fbia PJRT backend compiles against this surface; every operation
//! fails at runtime with [`Error::Unimplemented`]. Swap the path dependency
//! in `rust/Cargo.toml` for the registry crate to execute real HLO.

use std::fmt;

const STUB_MSG: &str =
    "xla stub: built offline without the real XLA/PJRT runtime (see rust/README.md)";

/// Error type matching the shape the PJRT backend expects.
#[derive(Debug)]
pub enum Error {
    Unimplemented,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{STUB_MSG}")
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types used when uploading raw byte buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S8,
    S32,
    F16,
    F32,
}

/// Primitive types used for literal conversion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    S8,
    S32,
    F16,
    F32,
}

/// PJRT client handle (stub: creation always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unimplemented)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unimplemented)
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unimplemented)
    }

    pub fn buffer_from_host_raw_bytes(
        &self,
        _ty: ElementType,
        _bytes: &[u8],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unimplemented)
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unimplemented)
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable (stub: execution always fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unimplemented)
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unimplemented)
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unimplemented)
    }
}

/// Host literal (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _bytes: &[u8],
    ) -> Result<Literal> {
        Err(Error::Unimplemented)
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unimplemented)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unimplemented)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unimplemented)
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        Err(Error::Unimplemented)
    }
}
