//! Integration: compiler → simulator pipeline over all six Table I models,
//! plus cross-cutting invariants the paper's evaluation depends on.
//! No artifacts needed — pure L3.

use fbia::config::Config;
use fbia::graph::models::ModelId;
use fbia::graph::TensorKind;
use fbia::sim::{simulate_model, simulate_model_batch};

#[test]
fn compile_then_simulate_every_model() {
    let cfg = Config::default();
    for id in ModelId::ALL {
        let r = simulate_model(id, &cfg, 50).unwrap_or_else(|e| panic!("{id:?}: {e}"));
        assert!(r.latency_s > 0.0 && r.latency_s < 10.0, "{id:?}: {}", r.latency_s);
        assert!(r.qps.is_finite() && r.qps > 0.0);
        assert!(!r.op_breakdown.is_empty());
        let share_sum: f64 = r.op_breakdown.iter().map(|(_, v)| v).sum();
        assert!((share_sum - 1.0).abs() < 1e-6, "{id:?}: shares sum {share_sum}");
    }
}

#[test]
fn fewer_cards_hurt_recsys_capacity() {
    // shrinking the node must eventually fail (tables stop fitting) or slow
    // down — it can never get faster
    let mut small = Config::default();
    small.node.cards = 3;
    small.compiler.sls_cards = 3;
    let big = Config::default();
    let r_big = simulate_model(ModelId::RecsysBase, &big, 50).unwrap();
    match simulate_model(ModelId::RecsysBase, &small, 50) {
        Ok(r_small) => assert!(r_small.qps <= r_big.qps * 1.05),
        Err(e) => assert!(e.to_string().contains("fit"), "{e}"),
    }
}

#[test]
fn complex_recsys_doesnt_fit_three_cards() {
    // >100B params at mixed int4/int8 needs more than 3x16 GB
    let mut cfg = Config::default();
    cfg.node.cards = 3;
    cfg.compiler.sls_cards = 3;
    assert!(simulate_model(ModelId::RecsysComplex, &cfg, 10).is_err());
}

#[test]
fn faster_cards_scale_throughput() {
    let slow = Config::default();
    let mut fast = Config::default();
    fast.node.card.peak_tops_int8 = 75.0;
    fast.node.card.peak_tflops_fp16 = 10.0;
    fast.node.card.lpddr_bw = 120e9;
    let a = simulate_model(ModelId::RegNetY, &slow, 50).unwrap();
    let b = simulate_model(ModelId::RegNetY, &fast, 50).unwrap();
    assert!(b.qps > a.qps * 1.3, "fast {} slow {}", b.qps, a.qps);
}

#[test]
fn batch_scaling_monotone_for_dlrm() {
    let cfg = Config::default();
    let mut last_items = 0.0;
    for b in [16usize, 32, 64] {
        let r = simulate_model_batch(ModelId::RecsysComplex, b, &cfg, 50).unwrap();
        assert!(r.items_per_s >= last_items * 0.9, "batch {b}: {} < {last_items}", r.items_per_s);
        last_items = r.items_per_s;
    }
}

#[test]
fn optimizations_never_hurt_latency() {
    // each §VI-C flag on must be <= off (within noise) for recsys latency
    let base = Config::default();
    let r_on = simulate_model(ModelId::RecsysComplex, &base, 50).unwrap();
    for flag in ["p2p", "partial", "cmd", "fp16", "bcast"] {
        let mut off = base.clone();
        match flag {
            "p2p" => off.transfers.peer_to_peer = false,
            "partial" => off.transfers.partial_tensors = false,
            "cmd" => off.transfers.command_batching = false,
            "fp16" => off.transfers.fp16_dense_inputs = false,
            _ => off.transfers.fused_broadcast = false,
        }
        let r_off = simulate_model(ModelId::RecsysComplex, &off, 50).unwrap();
        assert!(
            r_on.latency_s <= r_off.latency_s * 1.001,
            "{flag}: on {} off {}",
            r_on.latency_s,
            r_off.latency_s
        );
    }
}

#[test]
fn quantization_shrinks_weights_below_fp16() {
    let cfg = Config::default();
    for id in [ModelId::ResNeXt101, ModelId::RegNetY] {
        let g = id.build();
        let q_bytes = g.weight_bytes() as f64;
        // fp16 everywhere would be ~2 bytes/param
        let fp16_bytes = 2.0 * g.param_count() as f64;
        assert!(q_bytes < fp16_bytes * 0.75, "{id:?}: {q_bytes} vs {fp16_bytes}");
    }
    let _ = cfg;
}

#[test]
fn graph_io_tensors_consistent_after_compile() {
    let cfg = Config::default();
    for id in ModelId::ALL {
        let g = id.build();
        let before: usize = g
            .tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Output)
            .count();
        let c = fbia::compiler::compile(&g, &cfg).unwrap();
        let after: usize = c
            .graph
            .tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Output)
            .count();
        assert_eq!(before, after, "{id:?} lost outputs in compilation");
        c.graph.validate().unwrap();
    }
}
