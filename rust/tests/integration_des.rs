//! Integration: the discrete-event simulation core and the unified
//! `Simulation` API over it. Invariants — seeded tie-break determinism
//! across des seeds and worker counts, timer cancellation for hedged
//! work, Little's-law sanity for a queue built directly on the event
//! heap — plus the API surface both tiers now share: one builder, one
//! report shape, one conservation check, and the reactive
//! dynamic-batching policy the event clock unlocks beating static.

use fbia::config::Config;
use fbia::platform::NodeSpec;
use fbia::runtime::Engine;
use fbia::serving::cluster::{Cluster, EventKind, NodeEvent, NodePolicy, Scenario};
use fbia::serving::fleet::{
    Arrival, DynamicBatch, FamilyMix, Fleet, FleetConfig, FleetRequest, RoutePolicy, TrafficGen,
};
use fbia::serving::simulation::{SimReport, Simulation};
use fbia::sim::des::{class, EventHeap};
use std::path::Path;
use std::sync::Arc;

fn engine() -> Arc<Engine> {
    // no artifacts dir in CI: the builtin manifest on the sim backend
    Arc::new(
        Engine::auto_with(Path::new("/nonexistent/artifacts"), Some("sim")).expect("engine"),
    )
}

fn traffic(eng: &Engine, cfg: &FleetConfig, mix: &str, n: usize) -> Vec<FleetRequest> {
    let mix = FamilyMix::parse(mix).unwrap();
    TrafficGen::new(11, mix, Arrival::Burst, eng.manifest(), cfg.recsys_batch)
        .expect("traffic")
        .take(n)
}

#[test]
fn seeded_tiebreaks_deterministic_across_seeds_and_workers() {
    // 3 des seeds x 3 worker counts: for a fixed seed, route() and
    // serve(w) must agree bit-for-bit on every modeled number — the heap's
    // tie-break order is a function of the seed, not of host scheduling
    let eng = engine();
    for des_seed in [1u64, 0xFB1A_0DE5, u64::MAX] {
        let cfg = FleetConfig { des_seed, ..FleetConfig::default() };
        let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
        let reqs = traffic(&eng, &cfg, "70/20/10", 40);
        let base = Simulation::fleet(Arc::clone(&fleet))
            .trace(reqs.clone())
            .run()
            .unwrap();
        assert!(base.conserved(), "seed {des_seed:#x}: completed+shed != offered");
        assert!(base.completed > 0);
        for workers in [1usize, 2, 4] {
            let run = Simulation::fleet(Arc::clone(&fleet))
                .trace(reqs.clone())
                .execute(workers)
                .run()
                .unwrap();
            assert!(run.conserved());
            assert_eq!(run.completed, base.completed, "seed {des_seed:#x} w{workers}");
            assert_eq!(run.shed, base.shed);
            assert_eq!(run.qps, base.qps, "qps must be bit-identical");
            assert_eq!(run.p50_ms, base.p50_ms);
            assert_eq!(run.p99_ms, base.p99_ms);
            assert_eq!(run.span_s, base.span_s);
        }
    }
}

#[test]
fn different_seeds_may_reorder_ties_but_conserve() {
    // the seed only permutes equal-time pops: offered/completed accounting
    // must not depend on it (a burst trace is all ties at t=0)
    let eng = engine();
    let mut reports: Vec<SimReport> = Vec::new();
    for des_seed in [7u64, 8, 9] {
        let cfg = FleetConfig { des_seed, ..FleetConfig::default() };
        let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
        let reqs = traffic(&eng, &cfg, "70/20/10", 40);
        let r = Simulation::fleet(fleet).trace(reqs).run().unwrap();
        assert!(r.conserved());
        reports.push(r);
    }
    assert!(reports.iter().all(|r| r.offered == reports[0].offered));
    assert!(reports.iter().all(|r| r.completed + r.shed == r.offered));
}

#[test]
fn hedge_timer_cancellation_on_the_event_heap() {
    // the hedged-request pattern: arm a hedge timer per request, cancel it
    // when the primary completes first; a cancelled timer must never pop
    #[derive(Debug, PartialEq)]
    enum Ev {
        Primary(usize),
        Hedge(usize),
    }
    let mut heap: EventHeap<Ev> = EventHeap::new(42);
    let mut hedge_ids = Vec::new();
    for i in 0..8 {
        let t = i as f64 * 0.1;
        // primaries 0..4 are fast (beat the hedge), 4..8 slow (hedge fires)
        let svc = if i < 4 { 0.05 } else { 0.5 };
        heap.push_class(t + svc, class::COMPLETION, Ev::Primary(i));
        hedge_ids.push(heap.push_class(t + 0.2, class::TIMER, Ev::Hedge(i)));
    }
    let mut primaries = 0;
    let mut hedges_fired = 0;
    let mut last = f64::NEG_INFINITY;
    while let Some(e) = heap.pop() {
        assert!(e.at_s >= last, "event clock must be monotone");
        last = e.at_s;
        match e.kind {
            Ev::Primary(i) => {
                primaries += 1;
                // completion wins the race: cancel the hedge (fast half only
                // — slow primaries finish after their hedge already fired)
                if i < 4 {
                    assert!(heap.cancel(hedge_ids[i]), "hedge {i} should be cancellable");
                    assert!(!heap.cancel(hedge_ids[i]), "double-cancel must be a no-op");
                }
            }
            Ev::Hedge(i) => {
                assert!(i >= 4, "hedge {i} fired although its primary completed first");
                hedges_fired += 1;
            }
        }
    }
    assert_eq!(primaries, 8);
    assert_eq!(hedges_fired, 4);
    assert_eq!(heap.now_s(), last);
    assert!(heap.is_empty());
}

#[test]
fn event_heap_queue_obeys_littles_law() {
    // D/D/1 on the raw heap at 80% utilization: arrivals every 1.0s,
    // deterministic 0.8s service, FIFO single server. L == lambda * W must
    // hold exactly for the time-averaged occupancy over the busy window.
    enum Ev {
        Arrive(usize),
        Complete(usize),
    }
    let n = 200usize;
    let (inter, svc) = (1.0f64, 0.8f64);
    let mut heap: EventHeap<Ev> = EventHeap::new(7);
    for i in 0..n {
        heap.push(i as f64 * inter, Ev::Arrive(i));
    }
    let mut server_free_at = 0.0f64;
    let mut spans: Vec<(f64, f64)> = Vec::new(); // (arrival, completion)
    while let Some(e) = heap.pop() {
        match e.kind {
            Ev::Arrive(i) => {
                let start = server_free_at.max(e.at_s);
                server_free_at = start + svc;
                heap.push_class(server_free_at, class::COMPLETION, Ev::Complete(i));
                spans.push((e.at_s, server_free_at));
            }
            Ev::Complete(_) => {}
        }
    }
    assert_eq!(spans.len(), n);
    let t_end = spans.last().unwrap().1;
    let horizon = t_end; // first arrival is at 0
    // L: time-integral of number-in-system / horizon (exact, piecewise)
    let area: f64 = spans.iter().map(|&(a, f)| f - a).sum();
    let l = area / horizon;
    let lambda = n as f64 / horizon;
    let w = area / n as f64;
    assert!(
        (l - lambda * w).abs() < 1e-9,
        "Little's law must hold exactly: L {l} vs lambda*W {}",
        lambda * w
    );
    // sub-critical D/D/1 never queues: every wait equals the service time
    assert!(spans.iter().all(|&(a, f)| (f - a - svc).abs() < 1e-9));
}

#[test]
fn dynamic_batching_beats_static_on_nlp_burst() {
    // the reactive policy the event clock unlocks: same engine, same
    // trace, the only difference is queue-depth-triggered batch growth
    let eng = engine();
    let static_cfg = FleetConfig::default();
    assert!(static_cfg.dynamic_batch.is_none(), "default fleet must be static");
    let dyn_cfg =
        FleetConfig { dynamic_batch: Some(DynamicBatch::default()), ..static_cfg.clone() };
    let reqs = traffic(&eng, &static_cfg, "0/100/0", 96);
    let stat = Simulation::fleet(Arc::new(Fleet::new(eng.clone(), static_cfg).unwrap()))
        .trace(reqs.clone())
        .run()
        .unwrap();
    let dynr = Simulation::fleet(Arc::new(Fleet::new(eng.clone(), dyn_cfg).unwrap()))
        .trace(reqs)
        .run()
        .unwrap();
    assert!(stat.conserved() && dynr.conserved());
    assert_eq!(stat.offered, 96);
    assert_eq!(dynr.offered, 96);
    assert!(dynr.shed <= stat.shed);
    assert!(
        dynr.qps > stat.qps,
        "dynamic batching ({} QPS) must beat static ({} QPS) under same-shape burst pressure",
        dynr.qps,
        stat.qps
    );
}

#[test]
fn simulation_api_is_uniform_across_tiers() {
    // one builder, one report shape: the same trace through both tiers
    // yields reports that satisfy the same invariants, and tier-specific
    // fields are populated exactly where they belong
    let eng = engine();
    let fcfg = FleetConfig { replicas: 2, ..FleetConfig::default() };
    let fleet = Arc::new(Fleet::new(eng.clone(), fcfg.clone()).unwrap());
    let reqs = traffic(&eng, &fcfg, "70/20/10", 30);

    let f = Simulation::fleet(fleet)
        .card_policy(RoutePolicy::LeastOutstanding)
        .trace(reqs.clone())
        .run()
        .unwrap();
    assert_eq!(f.tier, "fleet");
    assert_eq!(f.card_policy, RoutePolicy::LeastOutstanding);
    assert!(f.node_policy.is_none() && f.fleet.is_some() && f.cluster.is_none());
    assert!(f.conserved());

    let specs = vec![NodeSpec::default(); 2];
    let cluster =
        Arc::new(Cluster::new(Path::new("/nonexistent/artifacts"), &Config::default(), &specs, fcfg).unwrap());
    let c = Simulation::cluster(Arc::clone(&cluster))
        .node_policy(NodePolicy::JoinShortestQueue)
        .card_policy(RoutePolicy::LeastOutstanding)
        .trace(reqs.clone())
        .run()
        .unwrap();
    assert_eq!(c.tier, "cluster");
    assert_eq!(c.node_policy, Some(NodePolicy::JoinShortestQueue));
    assert!(c.fleet.is_none() && c.cluster.is_some());
    assert!(c.conserved());

    // scenarios belong to the cluster tier; the fleet tier refuses them
    let fleet2 = Arc::new(Fleet::new(eng.clone(), FleetConfig::default()).unwrap());
    let err = Simulation::fleet(fleet2)
        .scenario(Scenario::new(vec![NodeEvent { at_s: 0.1, node: 0, kind: EventKind::Fail }]))
        .trace(reqs.clone())
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("cluster-tier"), "{err}");

    // the same scenario on the cluster tier runs and still conserves
    let killed = Simulation::cluster(cluster)
        .scenario(Scenario::new(vec![NodeEvent { at_s: 0.0, node: 0, kind: EventKind::Drain }]))
        .trace(reqs)
        .run()
        .unwrap();
    assert!(killed.conserved());

    // the bench bridge carries the headline numbers through unchanged
    let bench = f.bench_report("des_check", "sim");
    assert_eq!(bench.offered, f.offered);
    assert_eq!(bench.completed, f.completed);
    assert_eq!(bench.qps, f.qps);
    assert_eq!(bench.clock, "modeled");
}
