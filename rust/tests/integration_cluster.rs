//! Integration: the cluster tier. Invariants — request conservation across
//! nodes (completed + shed == offered), bit-deterministic modeled metrics
//! across runs and worker counts — plus the subsystem's headline
//! properties: a NIC-bound regime where cluster QPS is pinned by
//! `NicSpec.bw_bits` while the cards' modeled costs are untouched,
//! weighted-by-modeled-capacity routing beating round-robin on a
//! heterogeneous tier at equal shed, node fail/drain semantics, and the
//! capacity planner's failure-headroom recommendation holding under a
//! single-node failure drill.

use fbia::config::Config;
use fbia::platform::{CardSpec, NodeSpec};
use fbia::serving::cluster::plan::plan_capacity;
use fbia::serving::cluster::{
    Cluster, ClusterMetrics, EventKind, NodeEvent, NodePolicy, Scenario,
};
use fbia::serving::fleet::{Arrival, FamilyMix, FleetConfig, FleetRequest, RoutePolicy, TrafficGen};
use std::path::Path;
use std::sync::Arc;

const DIR: &str = "/nonexistent/artifacts"; // builtin manifest everywhere
const CARD: RoutePolicy = RoutePolicy::LatencyAware;

fn fleet_cfg() -> FleetConfig {
    // two replicas per family keep per-node prep cheap
    FleetConfig { replicas: 2, ..FleetConfig::default() }
}

fn cluster_of(specs: &[NodeSpec], fcfg: &FleetConfig) -> Arc<Cluster> {
    let cfg = Config::default();
    Arc::new(Cluster::new(Path::new(DIR), &cfg, specs, fcfg.clone()).expect("cluster"))
}

fn traffic(c: &Cluster, fcfg: &FleetConfig, n: usize, arrival: Arrival) -> Vec<FleetRequest> {
    let mix = FamilyMix::parse("70/20/10").unwrap();
    TrafficGen::new(11, mix, arrival, c.manifest(), fcfg.recsys_batch)
        .expect("traffic")
        .take(n)
}

/// A node whose cards run at a quarter of the stock peaks.
fn slow_node() -> NodeSpec {
    let base = NodeSpec::default();
    NodeSpec {
        card: CardSpec {
            peak_tops_int8: base.card.peak_tops_int8 / 4.0,
            peak_tflops_fp16: base.card.peak_tflops_fp16 / 4.0,
            lpddr_bw: base.card.lpddr_bw / 4.0,
            sram_bw: base.card.sram_bw / 4.0,
            ..base.card.clone()
        },
        ..base
    }
}

fn assert_conserved(m: &ClusterMetrics) {
    assert_eq!(
        m.cluster.completed + m.shed(),
        m.offered,
        "requests lost or invented (completed {} + shed {} != offered {})",
        m.cluster.completed,
        m.shed(),
        m.offered
    );
    let by_node: usize = m.per_node.iter().map(|n| n.metrics.completed).sum();
    assert_eq!(by_node, m.cluster.completed, "per-node completion mismatch");
    let node_items: usize = m.per_node.iter().map(|n| n.metrics.items).sum();
    assert_eq!(node_items, m.cluster.items, "per-node items mismatch");
    let node_offered: usize = m.per_node.iter().map(|n| n.offered).sum();
    assert_eq!(node_offered + m.shed_unroutable, m.offered, "per-node offered mismatch");
    let node_shed: usize = m.per_node.iter().map(|n| n.shed_admission + n.shed_failed).sum();
    assert_eq!(node_shed, m.shed_admission + m.shed_failed);
    let fam_offered: usize = m.per_family.iter().map(|f| f.offered).sum();
    let fam_completed: usize = m.per_family.iter().map(|f| f.metrics.completed).sum();
    let fam_shed: usize = m.per_family.iter().map(|f| f.shed).sum();
    assert_eq!(fam_offered, m.offered);
    assert_eq!(fam_completed, m.cluster.completed);
    assert_eq!(fam_shed, m.shed());
    assert_eq!(m.cluster.latency.count() as usize, m.cluster.completed);
}

#[test]
fn cluster_conserves_requests_across_nodes_under_every_policy() {
    let fcfg = fleet_cfg();
    let cluster = cluster_of(&[NodeSpec::default(), NodeSpec::default()], &fcfg);
    let reqs = traffic(&cluster, &fcfg, 60, Arrival::Burst);
    for policy in NodePolicy::ALL {
        let m = cluster.route(&reqs, policy, CARD, &Scenario::none()).unwrap();
        assert_eq!(m.offered, 60);
        assert_eq!(m.shed_failed + m.shed_unroutable, 0, "{:?}", policy);
        assert_conserved(&m);
        assert!(m.cluster_qps() > 0.0);
        // both nodes actually carried traffic
        assert!(m.per_node.iter().all(|n| n.metrics.completed > 0), "{policy:?}");
    }
    // identical specs share one prepared fleet (scheduling state lives in
    // the router, so sharing cannot couple the nodes)
    assert!(Arc::ptr_eq(&cluster.nodes()[0].fleet, &cluster.nodes()[1].fleet));
    let hetero = cluster_of(&[NodeSpec::default(), slow_node()], &fleet_cfg());
    assert!(!Arc::ptr_eq(&hetero.nodes()[0].fleet, &hetero.nodes()[1].fleet));
}

#[test]
fn modeled_metrics_bit_deterministic_across_runs_and_workers() {
    let fcfg = fleet_cfg();
    let cluster = cluster_of(&[NodeSpec::default(), NodeSpec::default()], &fcfg);
    let reqs = traffic(&cluster, &fcfg, 24, Arrival::Burst);
    // serve() executes real numerics with 1 then 4 workers; route() never
    // executes — all three must report bit-identical modeled metrics
    let a = cluster
        .serve(reqs.clone(), NodePolicy::WeightedCapacity, CARD, &Scenario::none(), 1)
        .unwrap();
    let b = cluster
        .serve(reqs.clone(), NodePolicy::WeightedCapacity, CARD, &Scenario::none(), 4)
        .unwrap();
    let c = cluster.route(&reqs, NodePolicy::WeightedCapacity, CARD, &Scenario::none()).unwrap();
    for m in [&a, &b, &c] {
        assert_conserved(m);
    }
    assert_eq!(a.cluster.wall_s, b.cluster.wall_s);
    assert_eq!(a.cluster.wall_s, c.cluster.wall_s);
    assert_eq!(a.cluster.latency.p50(), b.cluster.latency.p50());
    assert_eq!(a.cluster.latency.p99(), b.cluster.latency.p99());
    assert_eq!(a.cluster.latency.p50(), c.cluster.latency.p50());
    for ((na, nb), nc) in a.per_node.iter().zip(&b.per_node).zip(&c.per_node) {
        assert_eq!(na.busy_s, nb.busy_s);
        assert_eq!(na.busy_s, nc.busy_s);
        assert_eq!(na.metrics.completed, nb.metrics.completed);
        assert_eq!(na.nic_rx_busy_s, nc.nic_rx_busy_s);
        assert_eq!(na.metrics.latency.p99(), nc.metrics.latency.p99());
    }
}

#[test]
fn nic_bound_regime_caps_cluster_qps_without_touching_card_costs() {
    // a deliberately slow NIC makes the wire the bottleneck: halving its
    // line rate must measurably lower cluster throughput while every
    // card-level modeled cost stays bit-identical
    let fcfg = fleet_cfg();
    let nic_node = |bw_bits: f64| {
        let mut n = NodeSpec::default();
        n.nic.bw_bits = bw_bits;
        n
    };
    let full = cluster_of(&[nic_node(80e6)], &fcfg);
    let half = cluster_of(&[nic_node(40e6)], &fcfg);
    let fast = cluster_of(&[NodeSpec::default()], &fcfg); // 50 Gbps stock
    let reqs = traffic(&full, &fcfg, 40, Arrival::Burst);
    let m_full = full.route(&reqs, NodePolicy::RoundRobin, CARD, &Scenario::none()).unwrap();
    let m_half = half.route(&reqs, NodePolicy::RoundRobin, CARD, &Scenario::none()).unwrap();
    let m_fast = fast.route(&reqs, NodePolicy::RoundRobin, CARD, &Scenario::none()).unwrap();
    assert_eq!(m_full.shed(), 0);
    assert_eq!(m_half.shed(), 0);
    // NIC-bound: the slow-NIC tiers sit well below the stock-NIC tier...
    assert!(
        m_fast.cluster_qps() > 2.0 * m_full.cluster_qps(),
        "80 Mbit/s tier ({}) must be NIC-bound vs 50 Gbit/s ({})",
        m_full.cluster_qps(),
        m_fast.cluster_qps()
    );
    // ...and halving the line rate roughly halves throughput
    let ratio = m_full.cluster_qps() / m_half.cluster_qps();
    assert!(ratio > 1.4, "halving NIC bandwidth changed QPS only {ratio:.2}x");
    // card QPS is unchanged: identical modeled per-family request costs
    // and identical card busy time for the same admitted set
    assert_eq!(full.nodes()[0].fam_cost_s, half.nodes()[0].fam_cost_s);
    assert_eq!(full.nodes()[0].fam_cost_s, fast.nodes()[0].fam_cost_s);
    assert_eq!(m_full.per_node[0].busy_s, m_half.per_node[0].busy_s);
    // the NIC occupancy accounting agrees with the bottleneck story
    assert!(m_full.per_node[0].nic_rx_busy_s > 0.5 * m_full.cluster.wall_s);
}

#[test]
fn weighted_routing_beats_round_robin_on_heterogeneous_cluster() {
    // vendor-mix tier: one stock node + one node 4x slower. Round-robin
    // alternates blindly and the slow node's backlog gates the span;
    // weighted-by-modeled-capacity prices each node's own modeled costs
    // and shifts load to the fast node — more throughput at equal shed
    let fcfg = fleet_cfg();
    let cluster = cluster_of(&[NodeSpec::default(), slow_node()], &fcfg);
    let reqs = traffic(&cluster, &fcfg, 80, Arrival::Burst);
    let rr = cluster.route(&reqs, NodePolicy::RoundRobin, CARD, &Scenario::none()).unwrap();
    let wc = cluster.route(&reqs, NodePolicy::WeightedCapacity, CARD, &Scenario::none()).unwrap();
    assert_eq!(rr.shed(), 0, "round-robin shed {} of {}", rr.shed(), rr.offered);
    assert_eq!(wc.shed(), 0);
    assert_conserved(&rr);
    assert_conserved(&wc);
    assert!(
        wc.cluster_qps() > rr.cluster_qps(),
        "weighted {} QPS must beat round-robin {} on a vendor-mix tier",
        wc.cluster_qps(),
        rr.cluster_qps()
    );
    // and it does so by sending the slow node fewer requests
    assert!(
        wc.per_node[1].offered < rr.per_node[1].offered,
        "weighted must offload the slow node ({} vs {})",
        wc.per_node[1].offered,
        rr.per_node[1].offered
    );
    // the slow node's replicas really are modeled slower
    assert!(cluster.nodes()[1].fam_cost_s[0] > cluster.nodes()[0].fam_cost_s[0]);
}

#[test]
fn node_failure_sheds_in_flight_and_reroutes() {
    let fcfg = fleet_cfg();
    let cluster = cluster_of(&[NodeSpec::default(), NodeSpec::default()], &fcfg);
    let reqs = traffic(&cluster, &fcfg, 40, Arrival::Burst);
    let clean = cluster.route(&reqs, NodePolicy::RoundRobin, CARD, &Scenario::none()).unwrap();
    assert_eq!(clean.shed(), 0);
    // kill node 0 halfway through the modeled span: its undelivered
    // requests are shed, the rest of the burst was already routed
    let at = 0.5 * clean.cluster.wall_s;
    let drill =
        Scenario::new(vec![NodeEvent { at_s: at, node: 0, kind: EventKind::Fail }]);
    let m = cluster.route(&reqs, NodePolicy::RoundRobin, CARD, &drill).unwrap();
    assert_conserved(&m);
    assert!(m.shed_failed > 0, "a mid-span failure must shed in-flight work");
    assert_eq!(m.shed_admission, 0);
    assert_eq!(m.shed_unroutable, 0);
    assert!(m.cluster.completed < clean.cluster.completed);
    let failed = &m.per_node[0];
    assert_eq!(failed.failed_at_s, Some(at));
    assert!(failed.shed_failed > 0);
    assert!(failed.availability(m.cluster.wall_s) < 1.0);
    assert_eq!(m.per_node[1].failed_at_s, None);
    // determinism holds through scenarios too
    let m2 = cluster.route(&reqs, NodePolicy::RoundRobin, CARD, &drill).unwrap();
    assert_eq!(m.shed_failed, m2.shed_failed);
    assert_eq!(m.cluster.wall_s, m2.cluster.wall_s);
}

#[test]
fn drained_node_stops_taking_traffic_without_shedding() {
    let fcfg = fleet_cfg();
    let cluster = cluster_of(&[NodeSpec::default(), NodeSpec::default()], &fcfg);
    let reqs = traffic(&cluster, &fcfg, 30, Arrival::Burst);
    let drain =
        Scenario::new(vec![NodeEvent { at_s: 0.0, node: 0, kind: EventKind::Drain }]);
    let m = cluster.route(&reqs, NodePolicy::JoinShortestQueue, CARD, &drain).unwrap();
    assert_conserved(&m);
    assert_eq!(m.shed(), 0, "drain must not shed anything");
    assert_eq!(m.per_node[0].offered, 0, "a drained node takes no new traffic");
    assert_eq!(m.per_node[0].metrics.completed, 0);
    assert_eq!(m.cluster.completed, 30);
    assert_eq!(m.per_node[0].drained_at_s, Some(0.0));
    // draining everything leaves requests unroutable, not lost
    let all = Scenario::new(vec![
        NodeEvent { at_s: 0.0, node: 0, kind: EventKind::Drain },
        NodeEvent { at_s: 0.0, node: 1, kind: EventKind::Drain },
    ]);
    let m = cluster.route(&reqs, NodePolicy::RoundRobin, CARD, &all).unwrap();
    assert_conserved(&m);
    assert_eq!(m.shed_unroutable, 30);
    assert_eq!(m.cluster.completed, 0);
}

#[test]
fn capacity_planner_headroom_survives_single_node_failure() {
    // the acceptance property: size the tier for 1.5x one node's measured
    // throughput with one node of failure headroom, kill a node at target
    // load, and admission ("SLA") shed stays zero
    let cfg = Config::default();
    let fcfg = fleet_cfg();
    let mix = FamilyMix::parse("70/20/10").unwrap();
    let report = plan_capacity(
        Path::new(DIR),
        &cfg,
        &fcfg,
        mix,
        NodePolicy::WeightedCapacity,
        CARD,
        0.0, // auto: 1.5x measured node QPS
        1,
        200,
    )
    .unwrap();
    assert!(report.node_qps > 0.0);
    assert!(report.target_qps > report.node_qps, "the tier must need >1 node");
    assert!(report.nodes_needed >= 2);
    assert_eq!(report.nodes_total, report.nodes_needed + 1);
    assert_eq!(
        report.sla_shed_after_failure, 0,
        "recommended headroom must keep SLA shed at zero under a node failure"
    );
    assert!(report.survives_single_node_failure);
    assert!(report.drill_completed > 0);
    // the Fig. 1 growth series carries the headroom and never shrinks
    assert_eq!(report.growth.len(), 9);
    for w in report.growth.windows(2) {
        assert!(w[1].2 >= w[0].2);
    }
    assert!(report.growth[0].2 >= report.nodes_total);
}
