//! Integration: the serving stack over real artifact manifests — partitioned
//! DLRM equals the monolithic reference, NLP bucket switching works, CV
//! batch variants agree with each other. Always runs: `Engine::auto` falls
//! back to the builtin manifest + reference backend when `artifacts/` has
//! not been built.

use fbia::numerics::ops_ref;
use fbia::numerics::weights::WeightGen;
use fbia::runtime::Engine;
use fbia::serving::{batcher::Batcher, CvServer, NlpServer, RecsysServer, ServeOptions, WEIGHT_SEED};
use fbia::util::stats::cosine_similarity;
use fbia::workloads::{CvGen, NlpGen, RecsysGen};
use std::path::Path;
use std::sync::Arc;

fn engine() -> Arc<Engine> {
    // cargo runs test binaries with cwd = rust/; the AOT driver writes
    // artifacts/ at the repository root, one level up
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    Arc::new(Engine::auto(&dir).expect("engine"))
}

#[test]
fn recsys_partitioned_matches_reference_pipeline() {
    let e = engine();
    let m = e.manifest().clone();
    let batch = 16;
    let server = Arc::new(RecsysServer::new(e.clone(), batch, "fp32").unwrap());
    let mut gen = RecsysGen::from_manifest(9, batch, &m).unwrap();
    let req = gen.next();
    let scores = server.infer(&req).unwrap();
    let s = scores.as_f32().unwrap();
    assert_eq!(scores.shape(), &[batch, 1]);
    assert!(s.iter().all(|v| (0.0..=1.0).contains(v) && v.is_finite()));

    // cross-check the SLS partition against the rust reference directly
    let sparse = server.run_sls(&req).unwrap();
    let dim = m.config_usize("dlrm", "embed_dim").unwrap();
    let max_lookups = m.config_usize("dlrm", "max_lookups").unwrap();
    let mut wgen = WeightGen::new(WEIGHT_SEED);
    // table0 lives in shard0; regenerate it and pool by hand
    let art = m.get("dlrm_sls_shard0_b16").unwrap();
    let spec = art.inputs.iter().find(|s| s.name == "table0").unwrap();
    let table = wgen.fp_weight(spec);
    let pooled = ops_ref::sls(
        &table,
        dim,
        req.indices[0].as_i32().unwrap(),
        req.lengths[0].as_i32().unwrap(),
        batch,
        max_lookups,
    )
    .unwrap();
    let got = sparse.as_f32().unwrap();
    let num_tables = m.config_usize("dlrm", "num_tables").unwrap();
    for b in 0..batch {
        let gslice = &got[(b * num_tables) * dim..(b * num_tables) * dim + dim];
        let rslice = &pooled[b * dim..(b + 1) * dim];
        for (a, r) in gslice.iter().zip(rslice) {
            assert!((a - r).abs() < 1e-3, "{a} vs {r}");
        }
    }
}

#[test]
fn recsys_int8_close_to_fp32() {
    // the paper's accuracy gate: quantized scores track fp32 scores
    let e = engine();
    let m = e.manifest().clone();
    let batch = 16;
    let fp = Arc::new(RecsysServer::new(e.clone(), batch, "fp32").unwrap());
    let q = Arc::new(RecsysServer::new(e.clone(), batch, "int8").unwrap());
    let mut gen = RecsysGen::from_manifest(11, batch, &m).unwrap();
    let req = gen.next();
    let a = fp.infer(&req).unwrap();
    let b = q.infer(&req).unwrap();
    let cos = cosine_similarity(a.as_f32().unwrap(), b.as_f32().unwrap());
    assert!(cos > 0.98, "cosine {cos}"); // §V-A embedding-quality gate
}

#[test]
fn nlp_bucket_switching_end_to_end() {
    let e = engine();
    let server = Arc::new(NlpServer::new(e.clone()).unwrap());
    assert_eq!(server.buckets, vec![32, 64, 128]);
    let vocab = e.manifest().config_usize("xlmr", "vocab").unwrap();
    let mut gen = NlpGen::new(3, vocab, 120, 100.0);
    let reqs: Vec<_> = (0..8).map(|_| gen.next()).collect();
    let (metrics, waste) = server.serve_with(reqs, &ServeOptions::default()).unwrap();
    assert_eq!(metrics.items, 8);
    assert!(metrics.completed >= 2); // at least two batches (length spread)
    assert!((0.0..1.0).contains(&waste));
}

#[test]
fn nlp_max_batch_validated_up_front() {
    let e = engine();
    let server = Arc::new(NlpServer::new(e.clone()).unwrap());
    let cap = server.max_supported_batch();
    assert!(cap >= 1);
    let mut gen = NlpGen::new(3, 100, 120, 100.0);
    let reqs: Vec<_> = (0..4).map(|_| gen.next()).collect();
    // one past the largest compiled variant: must fail before any batch runs
    let err = server
        .serve_with(reqs.clone(), &ServeOptions { max_batch: cap + 1, ..ServeOptions::default() })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("compiled"), "{msg}");
    assert!(server
        .serve_with(reqs, &ServeOptions { max_batch: 0, ..ServeOptions::default() })
        .is_err());
}

#[test]
fn nlp_same_sentence_same_embedding_across_buckets() {
    // bucket choice must not change the pooled embedding materially
    // (cosine >= 0.98, the paper's embedding-quality bar)
    let e = engine();
    let server = NlpServer::new(e.clone()).unwrap();
    let tokens: Vec<i32> = (0..20).map(|i| (i * 37 % 800) as i32).collect();
    let mk = |bucket: usize| fbia::serving::batcher::NlpBatch {
        requests: vec![fbia::workloads::NlpRequest { tokens: tokens.clone(), arrival_s: 0.0 }],
        bucket,
    };
    let a = &server.run_batch(&mk(32)).unwrap()[0];
    let b = &server.run_batch(&mk(64)).unwrap()[0];
    let cos = cosine_similarity(a, b);
    assert!(cos > 0.98, "cosine across buckets {cos}");
}

#[test]
fn cv_batch1_and_batch4_agree() {
    let e = engine();
    let server = CvServer::new(e.clone()).unwrap();
    let mut gen = CvGen::new(5, server.image);
    let req4 = gen.next(4);
    let (logits4, _) = server.infer(&req4.image).unwrap();
    // run image 0 through the batch-1 net
    let img = req4.image.as_f32().unwrap();
    let one = fbia::numerics::HostTensor::f32(
        img[..server.image * server.image * 3].to_vec(),
        &[1, server.image, server.image, 3],
    );
    let (logits1, _) = server.infer(&one).unwrap();
    let c = server.classes;
    let cos = cosine_similarity(&logits4.as_f32().unwrap()[..c], logits1.as_f32().unwrap());
    assert!(cos > 0.999, "batch variants disagree: {cos}");
}

#[test]
fn batcher_integration_no_loss_under_load() {
    let mut b = Batcher::new(vec![32, 64, 128], 4, true);
    let mut gen = NlpGen::new(17, 100, 128, 100.0);
    let n = 100;
    for _ in 0..n {
        b.push(gen.next());
    }
    let mut total = 0;
    while let Some(batch) = b.pop(false).unwrap() {
        total += batch.requests.len();
    }
    for batch in b.drain().unwrap() {
        total += batch.requests.len();
    }
    assert_eq!(total + b.rejected, n);
}

#[test]
fn quantization_ne_degradation_within_budget() {
    // the paper's §V-A offline gate: int8 vs fp32 NE degradation should be
    // small (their production bar is 0.02-0.05%; on synthetic labels we
    // require < 1%, far tighter than the op-level error would suggest)
    let e = engine();
    let m = e.manifest().clone();
    let batch = 32;
    let fp = Arc::new(RecsysServer::new(e.clone(), batch, "fp32").unwrap());
    let q = Arc::new(RecsysServer::new(e.clone(), batch, "int8").unwrap());
    let mut gen = RecsysGen::from_manifest(23, batch, &m).unwrap();
    let mut fp_scores = Vec::new();
    let mut q_scores = Vec::new();
    let mut labels = Vec::new();
    let mut lrng = fbia::util::rng::Rng::new(99);
    for _ in 0..4 {
        let req = gen.next();
        let a = fp.infer(&req).unwrap();
        let b = q.infer(&req).unwrap();
        for (&pa, &pb) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
            fp_scores.push(pa);
            q_scores.push(pb);
            // labels sampled from the fp32 model's own probabilities so the
            // fp32 NE is meaningful
            labels.push(if (lrng.f64() as f32) < pa { 1.0 } else { 0.0 });
        }
    }
    let deg = fbia::util::stats::ne_degradation_pct(&fp_scores, &q_scores, &labels);
    assert!(deg.abs() < 1.0, "NE degradation {deg:.4}% exceeds budget");
}

#[test]
fn failure_injection_bad_requests_rejected_cleanly() {
    let e = engine();
    let server = Arc::new(RecsysServer::new(e.clone(), 16, "fp32").unwrap());
    // wrong batch: dense has batch 8, server compiled for 16
    let bad = fbia::workloads::RecsysRequest {
        dense: fbia::numerics::HostTensor::f32(vec![0.0; 8 * 256], &[8, 256]),
        indices: vec![
            fbia::numerics::HostTensor::i32(vec![0; 16 * 32], &[16, 32]);
            e.manifest().config_usize("dlrm", "num_tables").unwrap()
        ],
        lengths: vec![
            fbia::numerics::HostTensor::i32(vec![0; 16], &[16]);
            e.manifest().config_usize("dlrm", "num_tables").unwrap()
        ],
    };
    // must be an Err, not a panic or a wrong-shaped success
    assert!(server.infer(&bad).is_err());
}

/// Build a valid request, then poison one embedding index.
fn poisoned_request(e: &Arc<Engine>, batch: usize, idx_value: i32) -> fbia::workloads::RecsysRequest {
    let mut req = requests(e, 31, batch, 1).pop().unwrap();
    let max_lookups = e.manifest().config_usize("dlrm", "max_lookups").unwrap();
    let mut idx = req.indices[0].as_i32().unwrap().to_vec();
    idx[0] = idx_value;
    let mut len = req.lengths[0].as_i32().unwrap().to_vec();
    len[0] = len[0].max(1); // make sure the poisoned slot is unmasked
    req.indices[0] = fbia::numerics::HostTensor::i32(idx, &[batch, max_lookups]);
    req.lengths[0] = fbia::numerics::HostTensor::i32(len, &[batch]);
    req
}

#[test]
fn sls_out_of_range_index_is_error_not_panic() {
    // the headline regression: a request-supplied embedding index past the
    // table (or negative) must surface as Err with artifact/table context
    let e = engine();
    let server = Arc::new(RecsysServer::new(e.clone(), 16, "fp32").unwrap());
    let rows = e.manifest().config_usize("dlrm", "rows_per_table").unwrap();
    for bad in [rows as i32, i32::MAX, -1, i32::MIN] {
        let req = poisoned_request(&e, 16, bad);
        let err = server.infer(&req).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("out of range"), "idx {bad}: {msg}");
        assert!(msg.contains("table0"), "idx {bad} missing table context: {msg}");
    }
    // the same value inside the table still serves
    let req = poisoned_request(&e, 16, rows as i32 - 1);
    server.infer(&req).unwrap();
}

#[test]
fn sls_out_of_range_index_rejected_by_threaded_paths_too() {
    let e = engine();
    let rows = e.manifest().config_usize("dlrm", "rows_per_table").unwrap();
    let req = poisoned_request(&e, 16, rows as i32);
    let sharded = Arc::new(RecsysServer::with_threads(e.clone(), 16, "fp32", 4).unwrap());
    assert!(sharded.infer(&req).is_err());
    let server = Arc::new(RecsysServer::new(e.clone(), 16, "fp32").unwrap());
    assert!(server
        .serve_with(vec![req], &ServeOptions { workers: 4, ..ServeOptions::default() })
        .is_err());
}

#[test]
fn failure_injection_wrong_table_count_rejected() {
    let e = engine();
    let server = Arc::new(RecsysServer::new(e.clone(), 16, "fp32").unwrap());
    let mut req = poisoned_request(&e, 16, 0);
    req.indices.pop();
    req.lengths.pop();
    assert!(server.infer(&req).is_err());
}

fn requests(e: &Arc<Engine>, seed: u64, batch: usize, n: usize) -> Vec<fbia::workloads::RecsysRequest> {
    let mut gen = RecsysGen::from_manifest(seed, batch, e.manifest()).unwrap();
    (0..n).map(|_| gen.next()).collect()
}

#[test]
fn parallel_sls_matches_sequential_bit_for_bit() {
    let e = engine();
    let seq = Arc::new(RecsysServer::new(e.clone(), 16, "fp32").unwrap());
    let par = Arc::new(RecsysServer::with_threads(e.clone(), 16, "fp32", 4).unwrap());
    for req in requests(&e, 41, 16, 4) {
        let a = seq.run_sls(&req).unwrap();
        let b = par.run_sls(&req).unwrap();
        assert_eq!(a, b); // bitwise: same per-shard compute, same scatter
    }
}

#[test]
fn serve_workers_matches_sequential_and_conserves_items() {
    let e = engine();
    let batch = 16;
    let server = Arc::new(RecsysServer::new(e.clone(), batch, "int8").unwrap());
    let reqs = requests(&e, 43, batch, 12);
    // scores must be identical regardless of how requests were scheduled
    let expect: Vec<_> = reqs.iter().map(|r| server.infer(r).unwrap()).collect();
    let metrics = server
        .serve_with(reqs.clone(), &ServeOptions { workers: 4, ..ServeOptions::default() })
        .unwrap();
    assert_eq!(metrics.completed, 12);
    assert_eq!(metrics.items, 12 * batch, "threaded metrics must conserve items");
    assert_eq!(metrics.latency.count(), 12);
    for (req, want) in reqs.iter().zip(&expect) {
        assert_eq!(&server.infer(req).unwrap(), want);
    }
}

#[test]
fn nlp_threaded_serve_conserves_items() {
    let e = engine();
    let server = Arc::new(NlpServer::new(e.clone()).unwrap());
    let vocab = e.manifest().config_usize("xlmr", "vocab").unwrap();
    let mut gen = NlpGen::new(7, vocab, 120, 100.0);
    let reqs: Vec<_> = (0..16).map(|_| gen.next()).collect();
    let (seq_m, seq_waste) = server.serve_with(reqs.clone(), &ServeOptions::default()).unwrap();
    let (par_m, par_waste) = server
        .serve_with(reqs, &ServeOptions { workers: 3, ..ServeOptions::default() })
        .unwrap();
    assert_eq!(par_m.items, 16, "threaded metrics must conserve requests");
    assert_eq!(par_m.items, seq_m.items);
    assert_eq!(par_m.completed, seq_m.completed); // same batches formed
    assert_eq!(par_m.latency.count(), seq_m.latency.count());
    assert_eq!(par_waste, seq_waste);
}

#[test]
fn cv_threaded_serve_conserves_items() {
    let e = engine();
    let server = Arc::new(CvServer::new(e.clone()).unwrap());
    let mut gen = CvGen::new(1, server.image);
    let metrics = server
        .serve_with(6, 4, &mut gen, &ServeOptions { workers: 3, ..ServeOptions::default() })
        .unwrap();
    assert_eq!(metrics.completed, 6);
    assert_eq!(metrics.items, 24);
    // unknown batch variant is rejected up front
    assert!(server.serve_with(2, 3, &mut gen, &ServeOptions::default()).is_err());
}

#[test]
fn failure_injection_missing_artifacts_dir() {
    let err = fbia::runtime::Engine::load(std::path::Path::new("/nonexistent/artifacts"));
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("manifest"), "{msg}");
}

#[test]
fn failure_injection_unknown_artifact_name() {
    let e = engine();
    assert!(e.compile("no_such_artifact").is_err());
    assert!(e.manifest().get("no_such_artifact").is_err());
}
