//! Integration: artifact manifest → execution backend → outputs vs the
//! independent Rust reference implementations (§V-C numerics validation,
//! end to end).
//!
//! Always runs: `Engine::auto` serves the builtin manifest through the
//! reference backend when `artifacts/` hasn't been built, and the AOT
//! artifacts (through the build's default backend) when it has.
//!
//! On `RefBackend` the backend and the reference share the numeric kernels,
//! so the comparison checks the *contract plumbing* (spec order,
//! uploaded-weights-vs-regenerated-weights agreement, output shapes) rather
//! than being a cross-implementation check; the value-level sanity
//! assertions below are the non-tautological part. With `--features pjrt`
//! and built artifacts the same tests become the full §V-C
//! compiled-kernels-vs-reference validation.

use fbia::numerics::validate;
use fbia::numerics::weights::WeightGen;
use fbia::runtime::Engine;
use fbia::serving::{test_inputs_for, WEIGHT_SEED};
use std::path::Path;
use std::sync::Arc;

fn engine() -> Arc<Engine> {
    // cargo runs test binaries with cwd = rust/; the AOT driver writes
    // artifacts/ at the repository root, one level up
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    Arc::new(Engine::auto(&dir).expect("engine"))
}

fn validate_artifact(engine: &Arc<Engine>, name: &str) -> validate::Validation {
    let manifest = engine.manifest().clone();
    let art = manifest.get(name).expect("artifact").clone();
    let inputs = test_inputs_for(&manifest, &art, 1234).expect("inputs");

    let mut gen = WeightGen::new(WEIGHT_SEED);
    let reference = validate::reference_outputs(&manifest, &art, &mut gen, &inputs).expect("ref");

    let mut gen2 = WeightGen::new(WEIGHT_SEED);
    let weights = gen2.weights_for(&art);
    let prepared = engine.prepare(name, weights).expect("prepare");
    let measured = prepared.run(&inputs).expect("run");

    assert_eq!(reference.len(), measured.len(), "{name}: output arity");
    let out = measured[0].as_f32().expect("out f32");
    // value-level sanity independent of the reference comparison: finite
    // everywhere, and not the all-zeros tensor a broken gather/FC yields
    assert!(out.iter().all(|v| v.is_finite()), "{name}: non-finite output");
    assert!(out.iter().any(|v| *v != 0.0), "{name}: all-zero output");
    validate::compare(name, reference[0].as_f32().expect("ref f32"), out)
}

#[test]
fn dlrm_sls_shard_matches_reference() {
    let e = engine();
    let v = validate_artifact(&e, "dlrm_sls_shard0_b16");
    assert!(v.passed, "{v:?}");
}

#[test]
fn dlrm_dense_fp32_matches_reference() {
    let e = engine();
    let v = validate_artifact(&e, "dlrm_dense_b16_fp32");
    assert!(v.passed, "{v:?}");
}

#[test]
fn dlrm_dense_int8_matches_reference() {
    // the quantized path: pallas quant_fc kernel inside the artifact vs the
    // integer reference — the core §V-C scenario
    let e = engine();
    let v = validate_artifact(&e, "dlrm_dense_b16_int8");
    assert!(v.passed, "{v:?}");
}

#[test]
fn xlmr_bucket_matches_reference() {
    let e = engine();
    let v = validate_artifact(&e, "xlmr_s32_b1");
    assert!(v.passed, "{v:?}");
}

#[test]
fn cv_trunk_matches_reference() {
    let e = engine();
    let v = validate_artifact(&e, "cv_trunk_b1");
    assert!(v.passed, "{v:?}");
}

#[test]
fn weights_are_deterministic_across_engines() {
    let e = engine();
    let art = e.manifest().get("dlrm_dense_b16_fp32").unwrap().clone();
    let a = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    let b = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    assert_eq!(a.len(), b.len());
    for ((na, ta), (nb, tb)) in a.iter().zip(&b) {
        assert_eq!(na, nb);
        assert_eq!(ta, tb);
    }
}

#[test]
fn prepared_model_concurrent_runs_match_sequential_bit_for_bit() {
    // the zero-copy weight env is shared (Arc) across threads: N concurrent
    // run()s over the same prepared model must reproduce the sequential
    // outputs exactly
    let e = engine();
    let manifest = e.manifest().clone();
    let art = manifest.get("dlrm_dense_b16_fp32").unwrap().clone();
    let weights = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    let prepared = Arc::new(e.prepare(&art.name, weights).unwrap());
    let inputs = Arc::new(test_inputs_for(&manifest, &art, 77).unwrap());
    let expect = prepared.run(&inputs).unwrap();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let prepared = Arc::clone(&prepared);
            let inputs = Arc::clone(&inputs);
            let expect = expect.clone();
            s.spawn(move || {
                for _ in 0..3 {
                    assert_eq!(prepared.run(&inputs).unwrap(), expect);
                }
            });
        }
    });
}

#[test]
fn xlmr_out_of_vocab_token_id_is_error_not_panic() {
    let e = engine();
    let manifest = e.manifest().clone();
    let art = manifest.get("xlmr_s32_b1").unwrap().clone();
    let weights = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    let prepared = e.prepare(&art.name, weights).unwrap();
    let vocab = manifest.config_usize("xlmr", "vocab").unwrap();
    let mut inputs = test_inputs_for(&manifest, &art, 5).unwrap();
    // poison one token id past the vocab; shape stays valid
    let shape = inputs[0].shape().to_vec();
    let mut ids = inputs[0].as_i32().unwrap().to_vec();
    ids[0] = vocab as i32;
    inputs[0] = fbia::numerics::HostTensor::i32(ids, &shape);
    let err = prepared.run(&inputs).unwrap_err();
    assert!(format!("{err:#}").contains("out of range"), "{err:#}");
}

#[test]
fn prepared_model_rejects_bad_shapes() {
    let e = engine();
    let art = e.manifest().get("cv_trunk_b1").unwrap().clone();
    let weights = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    let prepared = e.prepare("cv_trunk_b1", weights).unwrap();
    // wrong image shape must be rejected before reaching PJRT
    let bad = fbia::numerics::HostTensor::f32(vec![0.0; 12], &[2, 1, 2, 3]);
    assert!(prepared.run(&[bad]).is_err());
}
