//! Integration: AOT artifacts → PJRT runtime → outputs vs the independent
//! Rust reference implementations (§V-C numerics validation, end to end).
//!
//! Skips gracefully when `artifacts/` hasn't been built.

use fbia::numerics::validate;
use fbia::numerics::weights::WeightGen;
use fbia::runtime::Engine;
use fbia::serving::{test_inputs_for, WEIGHT_SEED};
use std::path::Path;
use std::sync::Arc;

fn engine() -> Option<Arc<Engine>> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Engine::load(dir).expect("engine")))
}

fn validate_artifact(engine: &Arc<Engine>, name: &str) -> validate::Validation {
    let manifest = engine.manifest().clone();
    let art = manifest.get(name).expect("artifact").clone();
    let inputs = test_inputs_for(&manifest, &art, 1234).expect("inputs");

    let mut gen = WeightGen::new(WEIGHT_SEED);
    let reference = validate::reference_outputs(&manifest, &art, &mut gen, &inputs).expect("ref");

    let mut gen2 = WeightGen::new(WEIGHT_SEED);
    let weights = gen2.weights_for(&art);
    let prepared = engine.prepare(name, &weights).expect("prepare");
    let measured = prepared.run(engine, &inputs).expect("run");

    assert_eq!(reference.len(), measured.len(), "{name}: output arity");
    validate::compare(
        name,
        reference[0].as_f32().expect("ref f32"),
        measured[0].as_f32().expect("out f32"),
    )
}

#[test]
fn dlrm_sls_shard_matches_reference() {
    let Some(e) = engine() else { return };
    let v = validate_artifact(&e, "dlrm_sls_shard0_b16");
    assert!(v.passed, "{v:?}");
}

#[test]
fn dlrm_dense_fp32_matches_reference() {
    let Some(e) = engine() else { return };
    let v = validate_artifact(&e, "dlrm_dense_b16_fp32");
    assert!(v.passed, "{v:?}");
}

#[test]
fn dlrm_dense_int8_matches_reference() {
    // the quantized path: pallas quant_fc kernel inside the artifact vs the
    // integer reference — the core §V-C scenario
    let Some(e) = engine() else { return };
    let v = validate_artifact(&e, "dlrm_dense_b16_int8");
    assert!(v.passed, "{v:?}");
}

#[test]
fn xlmr_bucket_matches_reference() {
    let Some(e) = engine() else { return };
    let v = validate_artifact(&e, "xlmr_s32_b1");
    assert!(v.passed, "{v:?}");
}

#[test]
fn cv_trunk_matches_reference() {
    let Some(e) = engine() else { return };
    let v = validate_artifact(&e, "cv_trunk_b1");
    assert!(v.passed, "{v:?}");
}

#[test]
fn weights_are_deterministic_across_engines() {
    let Some(e) = engine() else { return };
    let art = e.manifest().get("dlrm_dense_b16_fp32").unwrap().clone();
    let a = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    let b = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    assert_eq!(a.len(), b.len());
    for ((na, ta), (nb, tb)) in a.iter().zip(&b) {
        assert_eq!(na, nb);
        assert_eq!(ta, tb);
    }
}

#[test]
fn prepared_model_rejects_bad_shapes() {
    let Some(e) = engine() else { return };
    let art = e.manifest().get("cv_trunk_b1").unwrap().clone();
    let weights = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    let prepared = e.prepare("cv_trunk_b1", &weights).unwrap();
    // wrong image shape must be rejected before reaching PJRT
    let bad = fbia::numerics::HostTensor::f32(vec![0.0; 12], &[2, 1, 2, 3]);
    assert!(prepared.run(&e, &[bad]).is_err());
}
