//! Integration: the fleet router. Invariants — items/request conservation
//! across cards and families, bit-deterministic modeled metrics across runs
//! and worker counts, shed accounting under admission control — plus the
//! subsystem's headline property: latency-aware routing buys modeled node
//! throughput over round-robin at equal shed rate.

use fbia::config::Config;
use fbia::platform::CardSpec;
use fbia::runtime::builtin::builtin_manifest;
use fbia::runtime::{Clock, Engine, SimBackend};
use fbia::serving::fleet::{
    Arrival, Family, FamilyMix, Fleet, FleetConfig, FleetMetrics, FleetRequest, Placement,
    RoutePolicy, TrafficGen,
};
use fbia::workloads::NlpRequest;
use std::path::Path;
use std::sync::Arc;

fn engine(backend: &str) -> Arc<Engine> {
    // no artifacts dir in CI: all backends serve the builtin manifest
    Arc::new(Engine::auto_with(Path::new("/nonexistent/artifacts"), Some(backend)).expect("engine"))
}

fn traffic(eng: &Engine, cfg: &FleetConfig, n: usize) -> Vec<FleetRequest> {
    let mix = FamilyMix::parse("70/20/10").unwrap();
    TrafficGen::new(11, mix, Arrival::Burst, eng.manifest(), cfg.recsys_batch)
        .expect("traffic")
        .take(n)
}

fn assert_conserved(m: &FleetMetrics) {
    assert_eq!(m.node.completed + m.shed, m.offered, "requests lost or invented");
    let by_card: usize = m.per_card.iter().map(|c| c.metrics.completed).sum();
    assert_eq!(by_card, m.node.completed, "per-card completion mismatch");
    let card_items: usize = m.per_card.iter().map(|c| c.metrics.items).sum();
    assert_eq!(card_items, m.node.items, "per-card items mismatch");
    let fam_offered: usize = m.per_family.iter().map(|f| f.offered).sum();
    let fam_completed: usize = m.per_family.iter().map(|f| f.metrics.completed).sum();
    let fam_shed: usize = m.per_family.iter().map(|f| f.shed).sum();
    let fam_items: usize = m.per_family.iter().map(|f| f.metrics.items).sum();
    assert_eq!(fam_offered, m.offered);
    assert_eq!(fam_completed, m.node.completed);
    assert_eq!(fam_shed, m.shed);
    assert_eq!(fam_items, m.node.items);
    assert_eq!(m.node.latency.count() as usize, m.node.completed);
}

#[test]
fn fleet_conserves_items_across_cards_under_every_policy() {
    let eng = engine("sim");
    let cfg = FleetConfig::default();
    let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
    let reqs = traffic(&eng, &cfg, 60);
    for policy in RoutePolicy::ALL {
        let m = fleet.route(&reqs, policy).unwrap();
        assert_eq!(m.offered, 60);
        assert_conserved(&m);
        assert_eq!(m.node.clock, Clock::Modeled);
        assert!(m.node_qps() > 0.0);
    }
}

#[test]
fn modeled_metrics_bit_deterministic_across_runs_and_workers() {
    let eng = engine("sim");
    let cfg = FleetConfig::default();
    let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
    let reqs = traffic(&eng, &cfg, 24);
    // serve() executes real numerics with 1 then 4 workers; route() never
    // executes — all three must report bit-identical modeled metrics
    let a = fleet.serve(reqs.clone(), RoutePolicy::LatencyAware, 1).unwrap();
    let b = fleet.serve(reqs.clone(), RoutePolicy::LatencyAware, 4).unwrap();
    let c = fleet.route(&reqs, RoutePolicy::LatencyAware).unwrap();
    for m in [&a, &b, &c] {
        assert_eq!(m.node.clock, Clock::Modeled);
        assert_conserved(m);
    }
    assert_eq!(a.node.wall_s, b.node.wall_s);
    assert_eq!(a.node.wall_s, c.node.wall_s);
    assert_eq!(a.node.latency.p50(), b.node.latency.p50());
    assert_eq!(a.node.latency.p99(), b.node.latency.p99());
    assert_eq!(a.node.latency.p50(), c.node.latency.p50());
    for ((ca, cb), cc) in a.per_card.iter().zip(&b.per_card).zip(&c.per_card) {
        assert_eq!(ca.busy_s, cb.busy_s);
        assert_eq!(ca.busy_s, cc.busy_s);
        assert_eq!(ca.metrics.completed, cb.metrics.completed);
        assert_eq!(ca.metrics.latency.p99(), cc.metrics.latency.p99());
    }
}

#[test]
fn latency_aware_beats_round_robin_at_equal_shed_rate() {
    // the acceptance property: on the default 6-card node with a 70/20/10
    // mix, cost-aware routing strictly raises modeled node QPS without
    // shedding more
    let eng = engine("sim");
    let cfg = FleetConfig::default();
    let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
    let reqs = traffic(&eng, &cfg, 150);
    let rr = fleet.route(&reqs, RoutePolicy::RoundRobin).unwrap();
    let la = fleet.route(&reqs, RoutePolicy::LatencyAware).unwrap();
    // equal shed rate (none sheds under the default admission knobs)
    assert_eq!(rr.shed, 0, "round-robin shed {} of {}", rr.shed, rr.offered);
    assert_eq!(la.shed, 0);
    assert!(
        la.node_qps() > rr.node_qps(),
        "latency-aware {} QPS must strictly beat round-robin {}",
        la.node_qps(),
        rr.node_qps()
    );
    // and never at the cost of tail latency explosions
    assert!(la.node.latency.p99() <= rr.node.latency.p99() * 1.5);
}

#[test]
fn sla_admission_sheds_deterministically() {
    let eng = engine("sim");
    // budget = 3x the most expensive family's modeled request cost, probed
    // from a default fleet: every family admits at queue depth 0, and a
    // 120-request burst drives depths far past the budget
    let probe = Fleet::new(eng.clone(), FleetConfig::default()).unwrap();
    let r = probe.replicas();
    let max_bucket = *r.buckets.last().unwrap();
    let worst = r
        .recsys_request_cost_s(0)
        .max(r.nlp[0].cost(max_bucket).expect("bucket cost").total_s())
        .max(r.cv[0].cost.total_s());
    assert!(worst > 0.0);
    let fleet_cfg = |sla| FleetConfig { sla_budget_s: sla, ..FleetConfig::default() };
    let cfg = fleet_cfg(Some(3.0 * worst));
    let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
    let reqs = traffic(&eng, &cfg, 120);
    let a = fleet.route(&reqs, RoutePolicy::LatencyAware).unwrap();
    let b = fleet.route(&reqs, RoutePolicy::LatencyAware).unwrap();
    assert!(a.shed > 0, "a 3x-request-cost SLA must shed under a 120-request burst");
    assert!(a.node.completed > 0, "the SLA must not shed everything");
    assert_conserved(&a);
    assert_eq!(a.shed, b.shed, "shed accounting must be deterministic");
    assert_eq!(a.node.wall_s, b.node.wall_s);
    // a generous budget admits strictly more
    let open = Arc::new(Fleet::new(eng.clone(), fleet_cfg(None)).unwrap());
    let m = open.route(&reqs, RoutePolicy::LatencyAware).unwrap();
    assert!(m.node.completed > a.node.completed);
}

#[test]
fn bounded_queue_sheds_and_accounts() {
    let eng = engine("sim");
    let cfg = FleetConfig { max_queue: 2, ..FleetConfig::default() };
    let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
    let reqs = traffic(&eng, &cfg, 80);
    let m = fleet.route(&reqs, RoutePolicy::RoundRobin).unwrap();
    assert!(m.shed > 0, "a depth-2 queue must shed an 80-request burst");
    assert_conserved(&m);
    let recsys = &m.per_family[Family::Recsys.index()];
    assert!(recsys.shed > 0);
}

#[test]
fn overlong_nlp_requests_are_shed_not_fatal() {
    let eng = engine("sim");
    let cfg = FleetConfig::default();
    let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
    let max_bucket = *fleet.replicas().buckets.last().unwrap();
    let reqs = vec![FleetRequest::Nlp {
        arrival_s: 0.0,
        req: NlpRequest { tokens: vec![1; max_bucket + 1], arrival_s: 0.0 },
    }];
    let m = fleet.route(&reqs, RoutePolicy::LatencyAware).unwrap();
    assert_eq!(m.shed, 1);
    assert_eq!(m.node.completed, 0);
    assert_eq!(m.per_family[Family::Nlp.index()].shed, 1);
}

#[test]
fn wall_clock_fleet_serves_real_numerics() {
    let eng = engine("ref");
    let cfg = FleetConfig { replicas: 2, ..FleetConfig::default() };
    let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
    let reqs = traffic(&eng, &cfg, 12);
    // route-only planning is refused on wall clocks
    let err = fleet.route(&reqs, RoutePolicy::LatencyAware).unwrap_err().to_string();
    assert!(err.contains("modeled clock"), "{err}");
    let m = fleet.serve(reqs, RoutePolicy::LeastOutstanding, 3).unwrap();
    assert_eq!(m.node.clock, Clock::Wall);
    assert_eq!(m.shed, 0);
    assert_eq!(m.node.completed, 12);
    assert_conserved(&m);
    assert!(m.node.wall_s > 0.0);
}

#[test]
fn placement_policies_land_replicas_where_expected() {
    let eng = engine("sim");
    let mk = |placement| {
        let cfg = FleetConfig { placement, ..FleetConfig::default() };
        Fleet::new(eng.clone(), cfg).unwrap()
    };
    // sls-affine: shard k pinned to card k, exactly like Engine::prepare
    let affine = mk(Placement::SlsAffine);
    let shard_cards: Vec<usize> = affine.replicas().sls.iter().map(|s| s.card).collect();
    assert_eq!(shard_cards, vec![0, 1, 2, 3]);
    // the non-shard replicas round-robin from card 0
    let dense_cards: Vec<usize> = affine.replicas().recsys.iter().map(|r| r.card).collect();
    assert_eq!(dense_cards, vec![0, 1, 2, 3]);
    let nlp_cards: Vec<usize> = affine.replicas().nlp.iter().map(|r| r.card).collect();
    assert_eq!(nlp_cards, vec![4, 5, 0, 1]);

    // pack: every replica, shards included, on card 0
    let pack = mk(Placement::Pack);
    assert!(pack.replicas().sls.iter().all(|s| s.card == 0));
    assert!(pack.replicas().cv.iter().all(|r| r.card == 0));

    // spread: one global cursor over everything
    let spread = mk(Placement::Spread);
    let shard_cards: Vec<usize> = spread.replicas().sls.iter().map(|s| s.card).collect();
    assert_eq!(shard_cards, vec![0, 1, 2, 3]);
    let dense_cards: Vec<usize> = spread.replicas().recsys.iter().map(|r| r.card).collect();
    assert_eq!(dense_cards, vec![4, 5, 0, 1]);
}

#[test]
fn pack_placement_costs_modeled_throughput() {
    let eng = engine("sim");
    let cfg = FleetConfig::default();
    let reqs = traffic(&eng, &cfg, 60);
    let affine = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
    let packed = Arc::new(
        Fleet::new(eng.clone(), FleetConfig { placement: Placement::Pack, ..cfg }).unwrap(),
    );
    let a = affine.route(&reqs, RoutePolicy::LatencyAware).unwrap();
    let p = packed.route(&reqs, RoutePolicy::LatencyAware).unwrap();
    assert!(
        a.node_qps() > p.node_qps(),
        "spreading the fleet ({}) must beat packing card 0 ({})",
        a.node_qps(),
        p.node_qps()
    );
}

#[test]
fn vendor_mix_card_slows_its_replicas() {
    // heterogeneous node: card 5's override quarters the compute peaks;
    // the replica that lands there must model slower than its twin on a
    // stock card
    let mut cfg = Config::default();
    let base = cfg.node.card.clone();
    cfg.node.card_overrides.push((
        5,
        CardSpec {
            peak_tops_int8: base.peak_tops_int8 / 4.0,
            peak_tflops_fp16: base.peak_tflops_fp16 / 4.0,
            lpddr_bw: base.lpddr_bw / 4.0,
            sram_bw: base.sram_bw / 4.0,
            ..base
        },
    ));
    let eng = Arc::new(Engine::with_backend(
        builtin_manifest(),
        Arc::new(SimBackend::new(cfg)),
    ));
    assert_eq!(eng.clock(), Clock::Modeled);
    let fleet = Fleet::new(eng.clone(), FleetConfig::default()).unwrap();
    // cv replicas land on cards 2,3,4,5 under sls-affine with 4 replicas
    let cv_cards: Vec<usize> = fleet.replicas().cv.iter().map(|r| r.card).collect();
    assert_eq!(cv_cards, vec![2, 3, 4, 5]);
    let slow = fleet.replicas().cv.iter().find(|r| r.card == 5).unwrap();
    let fast = fleet.replicas().cv.iter().find(|r| r.card == 4).unwrap();
    assert!(
        slow.cost.compute_s > fast.cost.compute_s,
        "slow card {} !> stock card {}",
        slow.cost.compute_s,
        fast.cost.compute_s
    );
}

#[test]
fn open_loop_queueing_obeys_littles_law_and_p99_rises_with_utilization() {
    // queueing sanity on the modeled clock: with Poisson arrivals the
    // time-averaged number of requests in the system (sampled from the
    // plan) must match arrival rate x mean latency (Little's law), and
    // p99 latency must rise monotonically as utilization climbs
    let eng = engine("sim");
    let cfg = FleetConfig::default();
    let fleet = Arc::new(Fleet::new(eng.clone(), cfg.clone()).unwrap());
    let mix = FamilyMix::parse("70/20/10").unwrap();

    // saturation throughput from a burst trace sets the load points
    let mut gen =
        TrafficGen::new(5, mix, Arrival::Burst, eng.manifest(), cfg.recsys_batch).unwrap();
    let burst = gen.take(200);
    let sat = fleet.route(&burst, RoutePolicy::LatencyAware).unwrap();
    let capacity_qps = sat.node_qps();
    assert!(capacity_qps > 0.0);

    let mut p99s = Vec::new();
    for utilization in [0.3, 0.6, 0.9] {
        let rate = utilization * capacity_qps;
        let mut gen = TrafficGen::new(
            5,
            mix,
            Arrival::Poisson { rate_qps: rate },
            eng.manifest(),
            cfg.recsys_batch,
        )
        .unwrap();
        let reqs = gen.take(400);
        let plan =
            fbia::serving::fleet::router::plan(fleet.replicas(), &reqs, RoutePolicy::LatencyAware, &cfg)
                .unwrap();
        // per-request (arrival, finish) intervals from the plan
        let spans: Vec<(f64, f64)> = plan
            .planned
            .iter()
            .filter_map(|p| p.route.as_ref().map(|r| (p.arrival_s, r.finish_s)))
            .collect();
        assert_eq!(spans.len(), 400, "open-loop load points must not shed");
        let t0 = reqs.first().unwrap().arrival_s();
        let span = plan.span_s;
        assert!(span > 0.0);
        // L: time-average number in system, sampled at 2000 points
        let samples = 2000;
        let mut in_system = 0usize;
        for k in 0..samples {
            let t = t0 + span * (k as f64 + 0.5) / samples as f64;
            in_system += spans.iter().filter(|&&(a, f)| a <= t && t < f).count();
        }
        let l = in_system as f64 / samples as f64;
        // lambda x W over the same window
        let lambda = spans.len() as f64 / span;
        let w = spans.iter().map(|&(a, f)| f - a).sum::<f64>() / spans.len() as f64;
        let lw = lambda * w;
        assert!(
            (l - lw).abs() <= 0.15 * lw.max(1e-12),
            "Little's law violated at {utilization} utilization: L {l} vs lambda*W {lw}"
        );
        // p99 from the same latencies, exactly (no histogram buckets)
        let mut lats: Vec<f64> = spans.iter().map(|&(a, f)| f - a).collect();
        lats.sort_by(|x, y| x.partial_cmp(y).unwrap());
        p99s.push(lats[(0.99 * (lats.len() - 1) as f64) as usize]);
    }
    assert!(
        p99s[0] <= p99s[1] && p99s[1] <= p99s[2],
        "p99 must rise monotonically with utilization: {p99s:?}"
    );
    assert!(
        p99s[2] > p99s[0],
        "p99 at 0.9 utilization ({}) must exceed 0.3 utilization ({})",
        p99s[2],
        p99s[0]
    );
}

#[test]
fn fleet_numerics_match_across_backends_and_policies() {
    // the same request stream served on ref and sim fleets must agree on
    // the planning-independent facts: everything admitted, same counts
    let cfg = FleetConfig { replicas: 2, ..FleetConfig::default() };
    let sim = engine("sim");
    let refe = engine("ref");
    let sim_fleet = Arc::new(Fleet::new(sim.clone(), cfg.clone()).unwrap());
    let ref_fleet = Arc::new(Fleet::new(refe.clone(), cfg.clone()).unwrap());
    let reqs = traffic(&sim, &cfg, 10);
    let a = sim_fleet.serve(reqs.clone(), RoutePolicy::RoundRobin, 2).unwrap();
    let b = ref_fleet.serve(reqs, RoutePolicy::RoundRobin, 2).unwrap();
    assert_eq!(a.node.completed, b.node.completed);
    assert_eq!(a.node.items, b.node.items);
    assert_eq!(a.node.clock, Clock::Modeled);
    assert_eq!(b.node.clock, Clock::Wall);
}
