//! Integration: the observability layer ([`fbia::obs`]) and its
//! do-no-harm contract. Tracing off must be invisible — bit-identical
//! `SimReport`s on both tiers and an allocation-free planner hot loop —
//! while tracing on yields spans that are monotone, nest inside their
//! request's lifetime, and sum to the reported end-to-end latency. The
//! NIC-bound acceptance drill lives here too: halving `bw_bits` on the
//! same seeded trace must flip the dominant stage from compute to network.

use fbia::config::Config;
use fbia::obs::{SegKind, Stage};
use fbia::platform::NodeSpec;
use fbia::runtime::Engine;
use fbia::serving::cluster::{Cluster, EventKind, NodeEvent, NodePolicy, Scenario};
use fbia::serving::fleet::{
    Arrival, FamilyMix, Fleet, FleetConfig, FleetRequest, NodePlanner, RoutePolicy, TrafficGen,
};
use fbia::serving::simulation::{SimReport, Simulation};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::path::Path;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Thread-local counting allocator (same pattern as integration_quantized):
// counts only THIS thread's allocations, so the zero-alloc assertion is
// immune to other test threads in the same binary.
// ---------------------------------------------------------------------------

struct TlCountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<usize> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for TlCountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: TlCountingAlloc = TlCountingAlloc;

fn my_allocs() -> usize {
    TL_ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Harness helpers (builtin manifest on the sim backend, like the DES tests)
// ---------------------------------------------------------------------------

fn engine() -> Arc<Engine> {
    Arc::new(
        Engine::auto_with(Path::new("/nonexistent/artifacts"), Some("sim")).expect("engine"),
    )
}

fn traffic(eng: &Engine, cfg: &FleetConfig, mix: &str, arrival: Arrival, n: usize) -> Vec<FleetRequest> {
    let mix = FamilyMix::parse(mix).unwrap();
    TrafficGen::new(11, mix, arrival, eng.manifest(), cfg.recsys_batch)
        .expect("traffic")
        .take(n)
}

/// Every externally observable number of the report, compared bit-for-bit.
fn assert_bit_identical(a: &SimReport, b: &SimReport, what: &str) {
    assert_eq!(a.offered, b.offered, "{what}: offered");
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(a.shed, b.shed, "{what}: shed");
    assert_eq!(a.shed_queue_full, b.shed_queue_full, "{what}: shed_queue_full");
    assert_eq!(a.shed_sla, b.shed_sla, "{what}: shed_sla");
    assert_eq!(a.shed_no_bucket, b.shed_no_bucket, "{what}: shed_no_bucket");
    assert_eq!(a.shed_failed, b.shed_failed, "{what}: shed_failed");
    assert_eq!(a.shed_unroutable, b.shed_unroutable, "{what}: shed_unroutable");
    assert_eq!(a.qps.to_bits(), b.qps.to_bits(), "{what}: qps");
    assert_eq!(a.items_per_s.to_bits(), b.items_per_s.to_bits(), "{what}: items/s");
    assert_eq!(a.p50_ms.to_bits(), b.p50_ms.to_bits(), "{what}: p50");
    assert_eq!(a.p99_ms.to_bits(), b.p99_ms.to_bits(), "{what}: p99");
    assert_eq!(a.span_s.to_bits(), b.span_s.to_bits(), "{what}: span");
    assert_eq!(a.stages, b.stages, "{what}: stage attribution");
}

fn cluster(specs: &[NodeSpec], fcfg: FleetConfig) -> Arc<Cluster> {
    Arc::new(
        Cluster::new(Path::new("/nonexistent/artifacts"), &Config::default(), specs, fcfg)
            .expect("cluster"),
    )
}

/// Mix-weighted mean modeled request cost over one node's per-family costs.
fn mean_cost_s(fam_cost_s: &[f64; 3], mix: FamilyMix) -> f64 {
    let w = [mix.recsys, mix.nlp, mix.cv];
    let total: f64 = w.iter().sum();
    fam_cost_s.iter().zip(w.iter()).map(|(c, w)| c * w).sum::<f64>() / total
}

// ---------------------------------------------------------------------------
// Tracing off: bit-identical reports, allocation-free hot loop
// ---------------------------------------------------------------------------

#[test]
fn tracing_off_is_bit_identical_on_both_tiers() {
    let eng = engine();
    let fcfg = FleetConfig::default();
    let fleet = Arc::new(Fleet::new(eng.clone(), fcfg.clone()).unwrap());
    let reqs = traffic(&eng, &fcfg, "70/20/10", Arrival::Burst, 60);

    // fleet tier: two untraced runs agree (seeded determinism), and the
    // traced run's report is bit-identical to both (tracing neutrality)
    let a = Simulation::fleet(Arc::clone(&fleet)).trace(reqs.clone()).run().unwrap();
    let b = Simulation::fleet(Arc::clone(&fleet)).trace(reqs.clone()).run().unwrap();
    let (c, tracer) = Simulation::fleet(fleet).trace(reqs.clone()).run_traced().unwrap();
    assert_bit_identical(&a, &b, "fleet untraced repeat");
    assert_bit_identical(&a, &c, "fleet traced vs untraced");
    assert!(a.conserved() && c.conserved());
    // ...and the traced run did actually record something
    assert_eq!(tracer.requests().len(), reqs.len());
    assert!(!tracer.segs().is_empty());

    // cluster tier, including the NIC + node-router path
    let specs = vec![NodeSpec::default(); 2];
    let cl = cluster(&specs, fcfg);
    let a = Simulation::cluster(Arc::clone(&cl)).trace(reqs.clone()).run().unwrap();
    let (b, tracer) = Simulation::cluster(cl).trace(reqs.clone()).run_traced().unwrap();
    assert_bit_identical(&a, &b, "cluster traced vs untraced");
    assert!(a.conserved());
    assert_eq!(tracer.requests().len(), reqs.len());
}

#[test]
fn untraced_planner_hot_loop_is_alloc_free() {
    let eng = engine();
    let fcfg = FleetConfig::default();
    let fleet = Fleet::new(eng.clone(), fcfg.clone()).unwrap();
    let replicas = fleet.replicas();
    let reqs = traffic(&eng, &fcfg, "70/20/10", Arrival::Burst, 32);

    let mut p = NodePlanner::new(replicas.cards);
    // warmup pass: identical request sequence against an idle node, so the
    // per-card queues reach their steady-state capacity
    for (i, r) in reqs.iter().enumerate() {
        let t = i as f64;
        p.prune(t);
        let _ = p.step(replicas, r, i, t, RoutePolicy::LatencyAware, &fcfg);
    }
    p.prune(1e9);

    // steady state: same deterministic sequence, warm queues, tape off —
    // the routing hot loop must not touch the heap at all
    let before = my_allocs();
    for (i, r) in reqs.iter().enumerate() {
        let t = 1e4 + i as f64;
        p.prune(t);
        let _ = p.step(replicas, r, i, t, RoutePolicy::LatencyAware, &fcfg);
    }
    let delta = my_allocs() - before;
    assert_eq!(
        delta, 0,
        "{delta} heap allocations in the untraced planner hot loop across {} requests",
        reqs.len()
    );
    p.prune(1e9);

    // with the tape enabled the same loop records occupancy segments (so
    // the zero above is not vacuous: this is where the cost lives)
    p.enable_tape();
    let before = my_allocs();
    for (i, r) in reqs.iter().enumerate() {
        let t = 2e4 + i as f64;
        p.prune(t);
        let _ = p.step(replicas, r, i, t, RoutePolicy::LatencyAware, &fcfg);
    }
    assert!(my_allocs() > before, "enabled tape must record (and allocate)");
    assert!(!p.take_tape().is_empty());
}

// ---------------------------------------------------------------------------
// Tracing on: span invariants
// ---------------------------------------------------------------------------

#[test]
fn traced_spans_are_monotone_nested_and_sum_to_latency() {
    let eng = engine();
    let fcfg = FleetConfig::default();
    let fleet = Arc::new(Fleet::new(eng.clone(), fcfg.clone()).unwrap());
    // burst traffic: heavy queueing, so the queue residual is exercised
    let reqs = traffic(&eng, &fcfg, "70/20/10", Arrival::Burst, 60);
    let (report, tracer) =
        Simulation::fleet(fleet).trace(reqs.clone()).run_traced().unwrap();
    assert!(report.conserved());
    assert_eq!(tracer.requests().len(), reqs.len());
    let completed = tracer.requests().iter().filter(|r| r.completed()).count();
    assert_eq!(completed, report.completed);

    // per-request: monotone lifecycle, non-negative stages, stage sums
    // matching the end-to-end latency within float tolerance
    for r in tracer.requests() {
        assert!(r.finish_s >= r.arrival_s, "req {}: finish before arrival", r.req);
        for stage in Stage::ALL {
            assert!(
                r.stage.get(stage) >= -1e-12,
                "req {}: negative {} attribution",
                r.req,
                stage.name()
            );
        }
        if r.completed() {
            let latency = r.latency_s();
            let sum = r.stage.total_s();
            assert!(
                (sum - latency).abs() <= 1e-9 * latency.max(1.0),
                "req {}: stage sum {sum} vs latency {latency}",
                r.req
            );
        }
    }

    // per-segment: well-formed intervals, nested inside their request's
    // arrival..finish lifetime
    for s in tracer.segs() {
        assert!(s.end_s >= s.start_s, "inverted segment on {}", s.kind.name());
        let r = &tracer.requests()[s.req];
        assert!(r.completed(), "segment recorded for a shed request {}", s.req);
        assert!(
            s.start_s >= r.arrival_s - 1e-12 && s.end_s <= r.finish_s + 1e-12,
            "req {}: {} segment [{}, {}] outside its lifetime [{}, {}]",
            s.req,
            s.kind.name(),
            s.start_s,
            s.end_s,
            r.arrival_s,
            r.finish_s
        );
    }

    // per-track: compute on a card serializes, so its timeline must be
    // non-overlapping; merged busy time bounds utilization at 1
    let cards = (0..).take_while(|&l| !tracer.timeline(SegKind::Compute, 0, l).is_empty());
    for lane in cards {
        let tl = tracer.timeline(SegKind::Compute, 0, lane);
        for w in tl.windows(2) {
            assert!(
                w[1].0 >= w[0].1 - 1e-9,
                "card {lane}: overlapping compute segments {:?} / {:?}",
                w[0],
                w[1]
            );
        }
        let u = tracer.utilization(SegKind::Compute, 0, lane);
        assert!((0.0..=1.0).contains(&u), "card {lane}: utilization {u}");
    }
}

// ---------------------------------------------------------------------------
// Failure scenarios: cause split conservation, tape survives node reset
// ---------------------------------------------------------------------------

#[test]
fn failure_scenario_conserves_with_cause_split() {
    let eng = engine();
    let fcfg = FleetConfig::default();
    let specs = vec![NodeSpec::default(); 2];
    let cl = cluster(&specs, fcfg.clone());
    let reqs = traffic(&eng, &fcfg, "70/20/10", Arrival::Burst, 60);
    let scenario =
        Scenario::new(vec![NodeEvent { at_s: 1e-4, node: 0, kind: EventKind::Fail }]);

    let plain = Simulation::cluster(Arc::clone(&cl))
        .node_policy(NodePolicy::WeightedCapacity)
        .scenario(scenario.clone())
        .trace(reqs.clone())
        .run()
        .unwrap();
    assert!(plain.conserved(), "cause split must account for every shed request");
    assert!(plain.shed_failed > 0, "killing a node mid-burst must lose in-flight work");

    // tracing stays neutral through the fail/reset path, and the work the
    // dead node did before failing stays visible in the timelines
    let (traced, tracer) = Simulation::cluster(cl)
        .node_policy(NodePolicy::WeightedCapacity)
        .scenario(scenario)
        .trace(reqs)
        .run_traced()
        .unwrap();
    assert_bit_identical(&plain, &traced, "cluster fail drill traced vs untraced");
    let failed = tracer.requests().iter().filter(|r| r.outcome == "shed-failed").count();
    assert_eq!(failed, traced.shed_failed);
}

// ---------------------------------------------------------------------------
// Acceptance: NIC-bound vs unconstrained dominant stage
// ---------------------------------------------------------------------------

#[test]
fn nic_bound_run_flips_dominant_stage_to_network() {
    let eng = engine();
    let fcfg = FleetConfig::default();
    let mix = FamilyMix::parse("70/20/10").unwrap();
    let specs = vec![NodeSpec::default(); 2];
    let stock = cluster(&specs, fcfg.clone());

    // open-loop Poisson well under capacity: with the tier mostly idle the
    // breakdown shows the intrinsic regime instead of saturation queueing
    let cost = mean_cost_s(&stock.nodes()[0].fam_cost_s, mix);
    let rate_qps = specs.len() as f64 / (12.0 * cost);
    let reqs = TrafficGen::new(11, mix, Arrival::Poisson { rate_qps }, eng.manifest(), fcfg.recsys_batch)
        .unwrap()
        .take(120);

    let fast = Simulation::cluster(Arc::clone(&stock)).trace(reqs.clone()).run().unwrap();
    assert!(fast.conserved());
    assert_eq!(
        fast.stages.dominant(),
        Some(Stage::Compute),
        "unconstrained run must be compute-bound (network {} vs compute {})",
        fast.stage_mean_s(Stage::Network),
        fast.stage_mean_s(Stage::Compute)
    );

    // same seed, same trace, NIC throttled: halve bw_bits (and keep
    // halving) until the mean wire time provably dominates the mean card
    // cost, flipping the dominant stage to network
    let mean_wire_bytes = reqs
        .iter()
        .map(|r| {
            let (i, o) = stock.wire().bytes(r);
            (i + o) as f64
        })
        .sum::<f64>()
        / reqs.len() as f64;
    let mut bw_bits = specs[0].nic.bw_bits / 2.0;
    while mean_wire_bytes * 8.0 / bw_bits < 4.0 * cost && bw_bits > 1.0 {
        bw_bits /= 2.0;
    }
    let mut slow_specs = specs.clone();
    for s in &mut slow_specs {
        s.nic.bw_bits = bw_bits;
    }
    let throttled = cluster(&slow_specs, fcfg);
    let (slow, tracer) =
        Simulation::cluster(throttled).trace(reqs).run_traced().unwrap();
    assert!(slow.conserved());
    assert_eq!(
        slow.stages.dominant(),
        Some(Stage::Network),
        "NIC-throttled run must be network-bound (network {} vs compute {})",
        slow.stage_mean_s(Stage::Network),
        slow.stage_mean_s(Stage::Compute)
    );
    assert!(slow.stage_mean_s(Stage::Network) > fast.stage_mean_s(Stage::Network));

    // the throttled wire is saturated enough to leave NIC occupancy
    // segments on both directions, and utilization stays bounded
    for (kind, name) in [(SegKind::NicRx, "rx"), (SegKind::NicTx, "tx")] {
        let segs = tracer.segs().iter().filter(|s| s.kind == kind).count();
        assert!(segs > 0, "throttled run recorded no NIC {name} segments");
        for node in 0..slow_specs.len() {
            let u = tracer.utilization(kind, node, 0);
            assert!((0.0..=1.0).contains(&u), "nic {name} node {node}: utilization {u}");
        }
    }
}
