//! Integration: the sim-clocked backend. Parity — `--backend sim` executes
//! the same reference kernels, so its outputs must be **bit-for-bit** equal
//! to `RefBackend` on all three model families — and determinism: modeled
//! latencies are a function of the artifact and the card, so the serving
//! histograms must be identical across runs and across worker counts.

use fbia::graph::models::ModelId;
use fbia::numerics::weights::WeightGen;
use fbia::runtime::{Clock, Engine};
use fbia::serving::{test_inputs_for, CvServer, NlpServer, RecsysServer, ServeOptions, WEIGHT_SEED};
use fbia::workloads::{CvGen, NlpGen, RecsysGen};
use std::path::Path;
use std::sync::Arc;

fn engine(backend: &str) -> Arc<Engine> {
    // no artifacts dir in CI: both backends serve the builtin manifest
    Arc::new(Engine::auto_with(Path::new("/nonexistent/artifacts"), Some(backend)).expect("engine"))
}

/// One representative artifact per family + precision corner.
const PARITY_ARTIFACTS: &[&str] = &[
    "dlrm_sls_shard0_b16",
    "dlrm_dense_b16_fp32",
    "dlrm_dense_b32_int8",
    "xlmr_s32_b1",
    "xlmr_s32_b4",
    "cv_trunk_b1",
];

#[test]
fn sim_outputs_bit_identical_to_ref_on_all_families() {
    let r = engine("ref");
    let s = engine("sim");
    assert_eq!(r.clock(), Clock::Wall);
    assert_eq!(s.clock(), Clock::Modeled);
    for name in PARITY_ARTIFACTS {
        let art = r.manifest().get(name).unwrap().clone();
        let inputs = test_inputs_for(r.manifest(), &art, 77).unwrap();
        let pr = r.prepare(name, WeightGen::new(WEIGHT_SEED).weights_for(&art)).unwrap();
        let ps = s.prepare(name, WeightGen::new(WEIGHT_SEED).weights_for(&art)).unwrap();
        let a = pr.run(&inputs).unwrap();
        let b = ps.run(&inputs).unwrap();
        assert_eq!(a, b, "{name}: sim output differs from ref");
        // and the sim side additionally carries a modeled card latency
        assert!(pr.modeled_run_s().is_none(), "{name}: ref must not model time");
        let t = ps.modeled_run_s().unwrap_or_else(|| panic!("{name}: sim must model time"));
        assert!(t > 0.0 && t.is_finite(), "{name}: modeled {t}");
    }
}

#[test]
fn sim_recsys_serving_identical_scores_and_modeled_metrics() {
    let batch = 16;
    let sim = engine("sim");
    let refe = engine("ref");
    let sim_server = Arc::new(RecsysServer::new(sim.clone(), batch, "int8").unwrap());
    let ref_server = Arc::new(RecsysServer::new(refe.clone(), batch, "int8").unwrap());
    let mut gen = RecsysGen::from_manifest(5, batch, sim.manifest()).unwrap();
    let req = gen.next();
    assert_eq!(
        sim_server.infer(&req).unwrap(),
        ref_server.infer(&req).unwrap(),
        "end-to-end DLRM scores must match bit-for-bit"
    );
    // SLS shards are pinned one per card, in compiler shard order
    assert_eq!(sim_server.shard_devices(), vec![0, 1, 2, 3]);
    let m = sim_server
        .serve_with(vec![req], &ServeOptions { pipeline: false, ..ServeOptions::default() })
        .unwrap();
    assert_eq!(m.clock, Clock::Modeled);
    assert!(m.wall_s > 0.0);
}

#[test]
fn sim_latencies_deterministic_across_runs_and_workers() {
    let batch = 32;
    let e = engine("sim");
    let server = Arc::new(RecsysServer::new(e.clone(), batch, "int8").unwrap());
    let mut gen = RecsysGen::from_manifest(9, batch, e.manifest()).unwrap();
    let reqs: Vec<_> = (0..8).map(|_| gen.next()).collect();

    let runs: Vec<_> = [1usize, 1, 4, 4]
        .iter()
        .map(|&w| {
            server
                .serve_with(
                    reqs.clone(),
                    &ServeOptions { workers: w, pipeline: false, ..ServeOptions::default() },
                )
                .unwrap()
        })
        .collect();
    // identical histograms across repeated runs AND across worker counts:
    // the modeled per-request latency does not depend on host scheduling
    for m in &runs {
        assert_eq!(m.clock, Clock::Modeled);
        assert_eq!(m.latency.count(), 8);
        assert_eq!(m.latency.p50(), runs[0].latency.p50());
        assert_eq!(m.latency.p99(), runs[0].latency.p99());
    }
    // wall time is deterministic per worker count and scales exactly
    assert_eq!(runs[0].wall_s, runs[1].wall_s);
    assert_eq!(runs[2].wall_s, runs[3].wall_s);
    assert!((runs[0].wall_s / runs[2].wall_s - 4.0).abs() < 1e-9);

    // the pipelined path is deterministic too, and never slower per unit
    // than the serial path's full latency
    let p1 = server.serve_with(reqs.clone(), &ServeOptions::default()).unwrap();
    let p2 = server.serve_with(reqs, &ServeOptions::default()).unwrap();
    assert_eq!(p1.wall_s, p2.wall_s);
    assert_eq!(p1.latency.p50(), runs[0].latency.p50());
    assert!(p1.wall_s <= runs[0].wall_s + 1e-12);
}

#[test]
fn sim_modeled_latency_within_dlrm_budget() {
    // the fig7 acceptance: modeled per-request latency vs the Table I band
    let e = engine("sim");
    let server = Arc::new(RecsysServer::new(e.clone(), 32, "int8").unwrap());
    let modeled = server.modeled_request_s().expect("sim models the request path");
    let budget = ModelId::RecsysComplex.latency_budget_s();
    assert!(
        modeled > 0.0 && modeled <= budget,
        "modeled request {modeled}s vs budget {budget}s"
    );
}

#[test]
fn sim_nlp_serving_deterministic_and_parity() {
    let sim = engine("sim");
    let refe = engine("ref");
    let sim_server = Arc::new(NlpServer::new(sim.clone()).unwrap());
    let ref_server = Arc::new(NlpServer::new(refe.clone()).unwrap());
    let vocab = sim.manifest().config_usize("xlmr", "vocab").unwrap();
    let mk = || {
        let mut gen = NlpGen::new(3, vocab, 120, 100.0);
        (0..10).map(|_| gen.next()).collect::<Vec<_>>()
    };
    // embeddings identical across backends
    let reqs = mk();
    let batch = fbia::serving::batcher::NlpBatch { requests: vec![reqs[0].clone()], bucket: 64 };
    assert_eq!(
        sim_server.run_batch(&batch).unwrap(),
        ref_server.run_batch(&batch).unwrap()
    );
    // metrics deterministic across runs and worker counts
    let (a, wa) = sim_server.serve_with(mk(), &ServeOptions::default()).unwrap();
    let (b, wb) = sim_server
        .serve_with(mk(), &ServeOptions { workers: 3, ..ServeOptions::default() })
        .unwrap();
    let (c, _) = sim_server
        .serve_with(mk(), &ServeOptions { workers: 3, ..ServeOptions::default() })
        .unwrap();
    assert_eq!(a.clock, Clock::Modeled);
    assert_eq!(a.latency.count(), b.latency.count());
    assert_eq!(a.latency.p50(), b.latency.p50());
    assert_eq!(a.latency.p99(), b.latency.p99());
    assert_eq!(b.latency.p50(), c.latency.p50());
    assert_eq!(b.wall_s, c.wall_s);
    assert_eq!(wa, wb);
}

#[test]
fn sim_cv_serving_deterministic_and_parity() {
    let sim = engine("sim");
    let refe = engine("ref");
    let sim_server = Arc::new(CvServer::new(sim.clone()).unwrap());
    let ref_server = Arc::new(CvServer::new(refe.clone()).unwrap());
    let mut gen = CvGen::new(5, sim_server.image);
    let req = gen.next(4);
    let (ls, es) = sim_server.infer(&req.image).unwrap();
    let (lr, er) = ref_server.infer(&req.image).unwrap();
    assert_eq!(ls, lr);
    assert_eq!(es, er);
    let mut g1 = CvGen::new(7, sim_server.image);
    let mut g2 = CvGen::new(7, sim_server.image);
    let a = sim_server.serve_with(6, 4, &mut g1, &ServeOptions::default()).unwrap();
    let b = sim_server
        .serve_with(6, 4, &mut g2, &ServeOptions { workers: 3, ..ServeOptions::default() })
        .unwrap();
    assert_eq!(a.clock, Clock::Modeled);
    assert_eq!(a.latency.p50(), b.latency.p50());
    assert_eq!(a.latency.p99(), b.latency.p99());
    assert_eq!(a.items, b.items);
}

#[test]
fn unknown_backend_rejected_with_valid_names() {
    let err = Engine::auto_with(Path::new("/nonexistent/artifacts"), Some("npu"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown backend 'npu'"), "{err}");
    assert!(err.contains("ref") && err.contains("sim"), "{err}");
}

#[test]
fn serve_options_validate_clock_and_backend_pins() {
    let e = engine("sim");
    let server = Arc::new(RecsysServer::new(e.clone(), 16, "int8").unwrap());
    let mut gen = RecsysGen::from_manifest(1, 16, e.manifest()).unwrap();
    let reqs = vec![gen.next()];
    // pins that match the engine pass through
    let opts = ServeOptions {
        clock: Some(Clock::Modeled),
        backend: Some("sim".to_string()),
        ..ServeOptions::default()
    };
    assert!(server.serve_with(reqs.clone(), &opts).is_ok());
    // a wrong pin fails up front, naming what the engine actually runs
    let err = server
        .serve_with(
            reqs.clone(),
            &ServeOptions { clock: Some(Clock::Wall), ..ServeOptions::default() },
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("modeled"), "{err:#}");
    let err = server
        .serve_with(
            reqs,
            &ServeOptions { backend: Some("ref".to_string()), ..ServeOptions::default() },
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("sim"), "{err:#}");
}
