//! Integration: the static analyzer end to end — the acceptance criteria
//! the PR gates on. Every builtin model and the default deployment config
//! must lint clean; a DLRM declared against a too-small card spec and an
//! SLA budget below the modeled floor must both be *rejected by lint*,
//! before any prepare/simulation runs; the `Engine::prepare` and config
//! loading gates refuse Error findings unless `--no-lint` switches them
//! off.

use fbia::analysis::{self, RuleId, Span};
use fbia::config::Config;
use fbia::graph::models::ModelId;
use fbia::platform::CardSpec;
use fbia::runtime::artifact::{ArtDType, Artifact, InputKind, InputSpec, OutputSpec};
use fbia::runtime::Engine;
use fbia::serving::fleet::{FamilyMix, FleetConfig};
use fbia::util::json::Json;
use std::path::PathBuf;

#[test]
fn every_builtin_model_lints_clean_on_the_default_node() {
    let cfg = Config::default();
    for id in ModelId::ALL {
        let r = analysis::lint_model(id, &cfg);
        assert!(r.is_empty(), "{} is not lint-clean:\n{}", id.name(), r.render());
    }
}

#[test]
fn default_deployment_lints_clean() {
    let cfg = Config::default();
    let r = FleetConfig::default()
        .lint(&cfg, FamilyMix::default(), None)
        .expect("deployment lint");
    assert!(r.is_empty(), "{}", r.render());
}

#[test]
fn dlrm_on_a_too_small_card_is_rejected_before_prepare() {
    // the acceptance case: a model that cannot fit the node spec becomes a
    // named lint error, not a runtime surprise
    let mut cfg = Config::default();
    cfg.node.card.lpddr_bytes = 1 << 30; // 1 GiB cards: no DLRM table fits
    let r = analysis::lint_model(ModelId::RecsysComplex, &cfg);
    assert!(r.has_errors(), "expected a fit failure:\n{}", r.render());
    let hits = r.by_rule(RuleId::PartitionFailed);
    assert!(!hits.is_empty(), "{}", r.render());
    assert!(
        matches!(&hits[0].span, Span::Model { model } if model.contains("recsys")),
        "span should name the model: {:?}",
        hits[0].span
    );
}

#[test]
fn sla_below_modeled_floor_is_rejected_before_any_des_run() {
    let cfg = Config::default();
    let fleet = FleetConfig { sla_budget_s: Some(1e-6), ..FleetConfig::default() };
    let mix = FamilyMix::new(1.0, 0.0, 0.0).unwrap();
    let r = fleet.lint(&cfg, mix, None).expect("deployment lint");
    let hits = r.by_rule(RuleId::SlaBelowModeledFloor);
    assert_eq!(hits.len(), 1, "{}", r.render());
    assert!(
        matches!(&hits[0].span, Span::Config { path } if path == "fleet.sla_budget_s"),
        "span should name the config field: {:?}",
        hits[0].span
    );
    // the gate form used by callers that want a hard stop
    assert!(r.check("fleet plan").is_err());
}

#[test]
fn prepare_gate_refuses_oversized_artifacts_unless_disabled() {
    let art = Artifact {
        name: "oversized".into(),
        file: PathBuf::from("oversized.bin"),
        model: "oversized".into(),
        role: "full".into(),
        batch: 1,
        seq: None,
        shard: None,
        inputs: vec![
            InputSpec {
                name: "w".into(),
                shape: vec![5 << 30, 1], // 20 GiB fp32 > 16 GiB LPDDR
                dtype: ArtDType::F32,
                kind: InputKind::Weight,
            },
            InputSpec {
                name: "x".into(),
                shape: vec![1, 8],
                dtype: ArtDType::F32,
                kind: InputKind::Input,
            },
        ],
        outputs: vec![OutputSpec { shape: vec![1, 8], dtype: ArtDType::F32 }],
    };

    let mut eng = Engine::builtin();
    let err = eng.prepare_on(art.clone(), Vec::new(), 0).unwrap_err().to_string();
    assert!(err.contains("lint error"), "gate should fire first: {err}");
    assert!(err.contains("partition-dram-overflow"), "rule should be named: {err}");

    // --no-lint: the gate steps aside and the normal weight checks take over
    eng.set_lint(false);
    let err = eng.prepare_on(art, Vec::new(), 0).unwrap_err().to_string();
    assert!(err.contains("weight mismatch"), "expected the pre-existing check: {err}");
}

#[test]
fn builtin_artifacts_pass_the_prepare_gate() {
    // with lint on (the default), every builtin artifact's resident bytes
    // fit the default card — the gate is invisible for correct configs
    let eng = Engine::builtin();
    for art in &eng.manifest().artifacts.clone() {
        let r = analysis::lint_artifact(art, &CardSpec::default(), 0);
        assert!(r.is_empty(), "{}:\n{}", art.name, r.render());
    }
}

#[test]
fn config_loading_gate_catches_what_validate_misses() {
    // max_queue == 0 passes Config::validate (it only checks serving knob
    // positivity elsewhere) but sheds every request — the lint gate stops it
    let j = Json::parse(r#"{"serving": {"max_queue": 0}}"#).unwrap();
    let err = Config::from_json(&j).unwrap_err().to_string();
    assert!(err.contains("queue-bound-zero"), "lint should name the rule: {err}");
    assert!(err.contains("--no-lint"), "error should advertise the escape hatch: {err}");

    // the escape hatch loads the same JSON untouched
    let cfg = Config::from_json_with(&j, false).expect("escape hatch");
    assert_eq!(cfg.serving.max_queue, 0);

    // a default-shaped config passes the gate unchanged
    let ok = Json::parse(r#"{"serving": {"max_queue": 64}}"#).unwrap();
    assert_eq!(Config::from_json(&ok).unwrap().serving.max_queue, 64);
}

#[test]
fn vendor_mix_override_overflow_names_the_card() {
    // a heterogeneous node where card 2 is tiny: the per-card DRAM proof
    // uses card_spec overrides, which Plan::check (base card only) misses
    let mut cfg = Config::default();
    cfg.node.card_overrides.push((2, CardSpec { lpddr_bytes: 8 << 20, ..CardSpec::default() }));
    let r = analysis::lint_model(ModelId::ResNeXt101, &cfg);
    let hits = r.by_rule(RuleId::PartitionDramOverflow);
    assert_eq!(hits.len(), 1, "{}", r.render());
    assert!(
        matches!(hits[0].span, Span::Partition { card: Some(2), .. }),
        "span should pin card 2: {:?}",
        hits[0].span
    );
}

#[test]
fn nic_rule_fires_only_at_infeasible_qps() {
    let cfg = Config::default();
    let fleet = FleetConfig::default();
    let hot = fleet.lint(&cfg, FamilyMix::default(), Some(1e9)).unwrap();
    assert_eq!(hot.by_rule(RuleId::NicBandwidthInsufficient).len(), 1, "{}", hot.render());
    let cold = fleet.lint(&cfg, FamilyMix::default(), Some(1.0)).unwrap();
    assert!(cold.is_empty(), "{}", cold.render());
}

#[test]
fn report_json_roundtrips_through_the_shared_parser() {
    let mut cfg = Config::default();
    cfg.node.card.lpddr_bytes = 1 << 30;
    let r = analysis::lint_model(ModelId::RecsysComplex, &cfg);
    let j = Json::parse(&r.to_json().to_string()).expect("self-emitted JSON parses");
    assert_eq!(j.get("errors").and_then(Json::as_usize), Some(r.errors()));
    let items = j.get("items").and_then(Json::as_arr).expect("items array");
    assert_eq!(items.len(), r.diagnostics.len());
    assert_eq!(
        items[0].get("rule").and_then(Json::as_str),
        Some(RuleId::PartitionFailed.name())
    );
}
