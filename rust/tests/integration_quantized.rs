//! Integration: the int8 serving path end-to-end — prepare-time row-wise
//! quantization vs the f32 reference on every model family, bit-determinism
//! of the cache-blocked kernels against their naive/serial forms, and the
//! zero-allocation property of the prepared reference hot path.

use fbia::numerics::ops_ref;
use fbia::numerics::quant::quantize_rowwise_int8;
use fbia::numerics::validate::{int8_family_budget, int8_plan, relative_l2};
use fbia::numerics::weights::WeightGen;
use fbia::numerics::{arena, HostTensor};
use fbia::runtime::{Engine, Precision, PrepareOptions};
use fbia::serving::{test_inputs_for, RecsysServer, ServeOptions, WEIGHT_SEED};
use fbia::util::rng::Rng;
use fbia::util::stats::cosine_similarity;
use fbia::workloads::RecsysGen;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Thread-local counting allocator: counts only THIS thread's heap
// allocations, so the zero-alloc assertion is immune to other test threads
// running concurrently in the same binary.
// ---------------------------------------------------------------------------

struct TlCountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<usize> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for TlCountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: TlCountingAlloc = TlCountingAlloc;

fn my_allocs() -> usize {
    TL_ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// int8-vs-f32 accuracy harness: every family, through the public Engine API
// ---------------------------------------------------------------------------

/// Prepare `name` at f32 and at int8 with identical weights, run identical
/// inputs through both, and require every f32 output pair to sit within the
/// family budget the prepare-time accuracy gate enforces.
fn check_family(name: &str) {
    let e = Engine::builtin();
    let art = e.manifest().get(name).expect("artifact").clone();
    let n_quantized = int8_plan(&art).iter().filter(|d| d.quantize).count();
    assert!(n_quantized > 0, "{name}: expected at least one quantizable weight");

    let wf = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    let wq = WeightGen::new(WEIGHT_SEED).weights_for(&art);
    let pf = e.prepare_with(name, wf, PrepareOptions::default()).expect("f32 prepare");
    let pq = e
        .prepare_with(name, wq, PrepareOptions { precision: Precision::Int8 })
        .expect("int8 prepare (accuracy gate)");
    assert_eq!(pf.precision, Precision::F32);
    assert_eq!(pq.precision, Precision::Int8);

    let inputs = test_inputs_for(e.manifest(), &art, 0xC0FFEE).expect("inputs");
    let of = pf.run(&inputs).expect("f32 run");
    let oq = pq.run(&inputs).expect("int8 run");
    assert_eq!(of.len(), oq.len());

    let budget = int8_family_budget(n_quantized);
    let mut any_differ = false;
    for (i, (f, q)) in of.iter().zip(&oq).enumerate() {
        let (f, q) = match (f.as_f32(), q.as_f32()) {
            (Some(f), Some(q)) => (f, q),
            _ => continue,
        };
        let rel = relative_l2(q, f);
        assert!(
            rel <= budget,
            "{name} output {i}: relative L2 {rel:.4} exceeds family budget {budget:.4} \
             ({n_quantized} quantized weights)"
        );
        assert!(
            cosine_similarity(q, f) > 0.98,
            "{name} output {i}: int8 cosine vs f32 too low"
        );
        any_differ |= f != q;
    }
    assert!(any_differ, "{name}: int8 outputs identical to f32 — quantization was a no-op");
}

#[test]
fn dlrm_sls_int8_within_budget() {
    check_family("dlrm_sls_shard0_b16");
}

#[test]
fn dlrm_dense_int8_within_budget() {
    check_family("dlrm_dense_b16_fp32");
}

#[test]
fn xlmr_int8_within_budget() {
    check_family("xlmr_s32_b1");
}

#[test]
fn cv_int8_within_budget() {
    check_family("cv_trunk_b1");
}

#[test]
fn serve_options_precision_mismatch_is_rejected() {
    let e = Arc::new(Engine::builtin());
    let batch = 16;
    let server = Arc::new(RecsysServer::new(e.clone(), batch, "fp32").unwrap());
    let mut gen = RecsysGen::from_manifest(5, batch, e.manifest()).unwrap();
    let reqs = vec![gen.next()];
    let err = server
        .serve_with(reqs, &ServeOptions { precision: Some(Precision::Int8), ..Default::default() })
        .unwrap_err();
    assert!(err.to_string().contains("int8"), "unhelpful precision error: {err}");
}

// ---------------------------------------------------------------------------
// Bit-determinism of the blocked kernels
// ---------------------------------------------------------------------------

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_normal_f32(&mut v, 1.0);
    v
}

/// Textbook fc loop: per output element, accumulate over t then add bias —
/// the order the blocked kernel must reproduce exactly.
fn fc_naive(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for t in 0..k {
                acc += x[i * k + t] * w[j * k + t];
            }
            y[i * n + j] = acc + b[j];
        }
    }
    y
}

#[test]
fn blocked_fc_bit_identical_to_naive_on_odd_shapes() {
    let mut rng = Rng::new(41);
    // shapes chosen to exercise every edge: below MR, below NR, exact
    // multiples, and remainders on both dimensions
    for &(m, k, n) in &[(1, 1, 1), (1, 7, 3), (3, 5, 2), (4, 4, 4), (5, 9, 13), (7, 16, 4), (33, 17, 9)] {
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        let naive = fc_naive(&x, &w, &b, m, k, n);
        let mut y = vec![0f32; m * n];
        ops_ref::fc_into(&x, &w, &b, m, k, n, &mut y);
        assert_eq!(y, naive, "fc_into diverged from naive at {m}x{k}x{n}");
        assert_eq!(ops_ref::fc(&x, &w, &b, m, k, n), naive, "fc diverged at {m}x{k}x{n}");
    }
}

/// The documented quant_fc formula, evaluated in exactly the reference
/// order: symmetric activation quantization, i32 GEMM, float epilogue
/// `(acc + rowsum·zp)·(xs·scale) + bias`.
#[allow(clippy::too_many_arguments)]
fn quant_fc_naive(
    x: &[f32],
    wq: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let absmax = x.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-8);
    let xs = absmax / 127.0;
    let xq: Vec<i32> = x.iter().map(|&v| (v / xs).round().clamp(-127.0, 127.0) as i32).collect();
    let mut y = vec![0f32; m * n];
    for i in 0..m {
        let row = &xq[i * k..(i + 1) * k];
        let rowsum: i32 = row.iter().sum();
        for j in 0..n {
            let mut acc: i32 = 0;
            for t in 0..k {
                acc += row[t] * wq[j * k + t] as i32;
            }
            let acc_f = acc as f32 + rowsum as f32 * zp[j];
            y[i * n + j] = acc_f * (xs * scale[j]) + bias[j];
        }
    }
    y
}

#[test]
fn blocked_quant_fc_bit_identical_to_naive_on_odd_shapes() {
    let mut rng = Rng::new(43);
    for &(m, k, n) in &[(1, 4, 2), (3, 5, 7), (4, 8, 4), (5, 9, 13), (6, 33, 10)] {
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, n * k);
        let b = randv(&mut rng, n);
        let q = quantize_rowwise_int8(&w, n, k);
        let naive = quant_fc_naive(&x, &q.q, &q.scale, &q.zp, &b, m, k, n);
        let mut y = vec![0f32; m * n];
        let mut xq = Vec::new();
        ops_ref::quant_fc_into(&x, &q.q, &q.scale, &q.zp, &b, m, k, n, &mut xq, &mut y);
        assert_eq!(y, naive, "quant_fc_into diverged from naive at {m}x{k}x{n}");
    }
}

#[test]
fn parallel_kernels_bit_identical_to_serial_above_threshold() {
    let mut rng = Rng::new(47);
    // fc: odd dims just above the parallel cutover -> uneven row tiles
    let (m, k, n) = (65, 257, 256);
    let x = randv(&mut rng, m * k);
    let w = randv(&mut rng, n * k);
    let b = randv(&mut rng, n);
    let serial = ops_ref::fc_serial(&x, &w, &b, m, k, n);
    for _ in 0..3 {
        assert_eq!(ops_ref::fc(&x, &w, &b, m, k, n), serial);
    }

    // conv2d: odd spatial dims and an odd channel count -> uneven channel
    // tiles across the pool
    let (cn, h, wd, cin, kk, cout) = (1, 33, 31, 64, 3, 65);
    let x = randv(&mut rng, cn * h * wd * cin);
    let w = randv(&mut rng, kk * kk * cin * cout);
    let b = randv(&mut rng, cout);
    let serial = ops_ref::conv2d_serial(&x, &w, &b, cn, h, wd, cin, kk, kk, cout, 1, 1);
    assert_eq!(ops_ref::conv2d(&x, &w, &b, cn, h, wd, cin, kk, kk, cout, 1, 1), serial);
}

#[test]
fn sls_q8_bit_identical_to_sls_over_dequantized_table() {
    let mut rng = Rng::new(53);
    let (rows, dim, batch, max_len) = (500, 48, 7, 11);
    let mut table = vec![0f32; rows * dim];
    rng.fill_normal_f32(&mut table, 0.1);
    let q = quantize_rowwise_int8(&table, rows, dim);
    // the dequantized table: exactly the values sls_q8 streams row by row
    let dq: Vec<f32> = (0..rows * dim)
        .map(|i| (q.q[i] as f32 + q.zp[i / dim]) * q.scale[i / dim])
        .collect();
    let indices: Vec<i32> = (0..batch * max_len).map(|_| rng.below(rows as u64) as i32).collect();
    let lengths: Vec<i32> = (0..batch).map(|b| (b % (max_len + 1)) as i32).collect();
    let mut out_q = vec![0f32; batch * dim];
    let mut out_f = vec![0f32; batch * dim];
    ops_ref::sls_q8_into(&q.q, &q.scale, &q.zp, dim, &indices, &lengths, batch, max_len, &mut out_q)
        .unwrap();
    ops_ref::sls_into(&dq, dim, &indices, &lengths, batch, max_len, &mut out_f).unwrap();
    assert_eq!(out_q, out_f);
}

#[test]
fn recsys_outputs_identical_across_worker_counts() {
    let batch = 16;
    let mut servers = Vec::new();
    for threads in [1usize, 4] {
        let e = Arc::new(Engine::builtin());
        servers.push(Arc::new(RecsysServer::with_threads(e, batch, "int8", threads).unwrap()));
    }
    let e = Engine::builtin();
    let mut gen = RecsysGen::from_manifest(13, batch, e.manifest()).unwrap();
    for _ in 0..3 {
        let req = gen.next();
        let a = servers[0].infer(&req).unwrap();
        let b = servers[1].infer(&req).unwrap();
        assert_eq!(
            a.as_f32().unwrap(),
            b.as_f32().unwrap(),
            "sharded-parallel SLS changed the scores"
        );
    }
}

// ---------------------------------------------------------------------------
// Zero-allocation steady state on the prepared reference path
// ---------------------------------------------------------------------------

#[test]
fn steady_state_ref_serving_is_alloc_free() {
    let e = Engine::builtin();
    for (name, precision) in
        [("dlrm_dense_b16_fp32", Precision::F32), ("dlrm_dense_b16_fp32", Precision::Int8)]
    {
        let art = e.manifest().get(name).unwrap().clone();
        let weights = WeightGen::new(WEIGHT_SEED).weights_for(&art);
        let prepared = e.prepare_with(name, weights, PrepareOptions { precision }).unwrap();
        let mut rng = Rng::new(17);
        let mut dense = vec![0f32; 16 * 256];
        let mut sparse = vec![0f32; 16 * 8 * 64];
        rng.fill_normal_f32(&mut dense, 1.0);
        rng.fill_normal_f32(&mut sparse, 0.1);
        let dense = HostTensor::f32(dense, &[16, 256]);
        let sparse = HostTensor::f32(sparse, &[16, 8, 64]);
        let inputs = [&dense, &sparse];
        // warmup until the arena pools stop growing
        for _ in 0..8 {
            let out = prepared.run_refs(&inputs).unwrap();
            arena::recycle_outputs(out);
        }
        let before = my_allocs();
        for _ in 0..32 {
            let out = prepared.run_refs(&inputs).unwrap();
            arena::recycle_outputs(out);
        }
        let delta = my_allocs() - before;
        assert_eq!(
            delta, 0,
            "{name} at {}: {delta} heap allocations across 32 steady-state runs",
            precision.name()
        );
    }
}
