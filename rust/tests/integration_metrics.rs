//! Integration: windowed telemetry and SLO burn-rate monitoring
//! ([`fbia::obs::metrics`] / [`fbia::obs::slo`]) plus the bench regression
//! gate ([`fbia::util::bench::compare`]). Pins the ISSUE-10 acceptance
//! criteria: windowed series reconcile bit-exactly with `SimReport`
//! totals, a node-fail drill trips the availability burn alert within
//! bounded windows and clears after recovery — deterministically across
//! DES seeds — monitoring off leaves reports bit-identical, and an
//! injected ≥10% QPS regression fails the bench diff.

use fbia::config::Config;
use fbia::obs::{MonitorReport, SloSpec, Tracer, STAGE_SAMPLE_CAP};
use fbia::platform::NodeSpec;
use fbia::runtime::Engine;
use fbia::serving::cluster::{Cluster, EventKind, NodeEvent, NodePolicy, Scenario};
use fbia::serving::fleet::{
    Arrival, Family, FamilyMix, Fleet, FleetConfig, FleetRequest, TrafficGen,
};
use fbia::serving::simulation::{SimReport, Simulation};
use fbia::serving::{RecsysServer, ServeOptions};
use fbia::util::bench::compare;
use fbia::util::json::Json;
use fbia::workloads::RecsysGen;
use std::path::Path;
use std::sync::Arc;

fn engine() -> Arc<Engine> {
    Arc::new(
        Engine::auto_with(Path::new("/nonexistent/artifacts"), Some("sim")).expect("engine"),
    )
}

fn cluster(specs: &[NodeSpec], fcfg: FleetConfig) -> Arc<Cluster> {
    Arc::new(
        Cluster::new(Path::new("/nonexistent/artifacts"), &Config::default(), specs, fcfg)
            .expect("cluster"),
    )
}

/// Mix-weighted mean modeled request cost over one node's per-family costs.
fn mean_cost_s(fam_cost_s: &[f64; 3], mix: FamilyMix) -> f64 {
    let w = [mix.recsys, mix.nlp, mix.cv];
    let total: f64 = w.iter().sum();
    fam_cost_s.iter().zip(w.iter()).map(|(c, w)| c * w).sum::<f64>() / total
}

/// The loosest Table I family budget in ms — the monitor CLI's default.
fn loose_budget_ms() -> f64 {
    Family::ALL.iter().map(|f| f.latency_budget_s() * 1e3).fold(f64::MIN, f64::max)
}

/// The CLI's probe calibration (see `fbia monitor`): peak simultaneous
/// in-flight count on `node` and the midpoint of the widest interval
/// holding it, restricted to midpoints ≤ `t_max`.
fn inflight_peak(tracer: &Tracer, node: usize, t_max: f64) -> (usize, f64) {
    let mut edges: Vec<(f64, i64)> = Vec::new();
    for r in tracer.requests() {
        if r.node == node && r.completed() && r.finish_s > r.arrival_s {
            edges.push((r.arrival_s, 1));
            edges.push((r.finish_s, -1));
        }
    }
    edges.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
    });
    let mut cur = 0i64;
    let mut best = (0i64, -1.0f64, 0.0f64);
    for i in 0..edges.len().saturating_sub(1) {
        cur += edges[i].1;
        let (a, b) = (edges[i].0, edges[i + 1].0);
        let mid = 0.5 * (a + b);
        if cur > 0 && mid <= t_max && (cur, b - a) > (best.0, best.1) {
            best = (cur, b - a, mid);
        }
    }
    (best.0.max(0) as usize, best.2)
}

/// One calibrated node-fail drill at `des_seed` (3 nodes, open-loop
/// Poisson at 1/4 of tier capacity — enough in-flight work to kill,
/// enough headroom that the survivors absorb the reroute and the alert
/// can clear). Returns the monitored report pair plus the fail geometry.
struct Drill {
    report: SimReport,
    monitor: MonitorReport,
    monitor2: MonitorReport,
    plain: SimReport,
    window_s: f64,
    fail_at_s: f64,
}

fn fail_drill(des_seed: u64, spec: &SloSpec) -> Drill {
    let eng = engine();
    let fcfg = FleetConfig { des_seed, ..FleetConfig::default() };
    let mix = FamilyMix::parse("70/20/10").unwrap();
    let specs = vec![NodeSpec::default(); 3];
    let cl = cluster(&specs, fcfg.clone());
    let cost = mean_cost_s(&cl.nodes()[0].fam_cost_s, mix);
    let rate_qps = specs.len() as f64 / (4.0 * cost);
    let reqs: Vec<FleetRequest> =
        TrafficGen::new(11, mix, Arrival::Poisson { rate_qps }, eng.manifest(), fcfg.recsys_batch)
            .unwrap()
            .take(360);
    let horizon_s = reqs.last().unwrap().arrival_s();

    let sim = |events: &[NodeEvent]| {
        let mut s = Simulation::cluster(Arc::clone(&cl))
            .node_policy(NodePolicy::WeightedCapacity)
            .trace(reqs.clone());
        if !events.is_empty() {
            s = s.scenario(Scenario::new(events.to_vec()));
        }
        s
    };
    let (_, probe) = sim(&[]).run_traced().unwrap();
    let (k, t_star) = inflight_peak(&probe, 0, 0.7 * horizon_s);
    assert!(k > 0, "probe must find in-flight work on node 0 at 25% utilization");
    let events = vec![NodeEvent { at_s: t_star, node: 0, kind: EventKind::Fail }];
    let window_s = (horizon_s / 24.0).min(2.0 * k as f64 / rate_qps).max(1e-6);

    let (report, _, monitor) = sim(&events).run_monitored(window_s, spec).unwrap();
    let (_, _, monitor2) = sim(&events).run_monitored(window_s, spec).unwrap();
    let plain = sim(&events).run().unwrap();
    Drill { report, monitor, monitor2, plain, window_s, fail_at_s: t_star }
}

// ---------------------------------------------------------------------------
// Windowed conservation on both tiers
// ---------------------------------------------------------------------------

#[test]
fn windowed_series_reconciles_on_both_tiers() {
    let eng = engine();
    let fcfg = FleetConfig::default();
    let mix = FamilyMix::parse("70/20/10").unwrap();
    let reqs: Vec<FleetRequest> =
        TrafficGen::new(11, mix, Arrival::Burst, eng.manifest(), fcfg.recsys_batch)
            .unwrap()
            .take(80);
    let spec = SloSpec::deployment_default(loose_budget_ms());

    // fleet tier (burst: admission sheds exercise the cause series)
    let fleet = Arc::new(Fleet::new(eng.clone(), fcfg.clone()).unwrap());
    let (report, _, monitor) = Simulation::fleet(fleet)
        .trace(reqs.clone())
        .run_monitored(0.002, &spec)
        .unwrap();
    assert!(report.conserved());
    assert!(report.windows_reconcile(), "fleet windows must reconcile with totals");
    let s = report.windows.as_ref().unwrap();
    assert!(s.windows > 0);
    assert_eq!(s.totals().offered as usize, report.offered);
    assert_eq!(s.totals().completed as usize, report.completed);
    assert_eq!(s.totals().shed() as usize, report.shed);
    assert_eq!(&monitor.series, s, "report carries the same series as the monitor");
    // every vector padded to the common length
    assert_eq!(s.qps.len(), s.windows);
    assert_eq!(s.p99_ms.len(), s.windows);
    assert_eq!(s.card_util.len(), s.windows);

    // cluster tier, including NIC utilization series
    let specs = vec![NodeSpec::default(); 2];
    let cl = cluster(&specs, fcfg);
    let (report, _, _) =
        Simulation::cluster(cl).trace(reqs).run_monitored(0.002, &spec).unwrap();
    assert!(report.conserved());
    assert!(report.windows_reconcile(), "cluster windows must reconcile with totals");
    let s = report.windows.as_ref().unwrap();
    assert!(s.card_util.iter().all(|&u| (0.0..=1.0 + 1e-9).contains(&u)));
    assert!(s.nic_util.iter().all(|&u| (0.0..=1.0 + 1e-9).contains(&u)));
    // small runs keep raw stage samples; retention is bounded either way
    assert!(!report.stages.capped());
    assert!(report.stages.footprint() <= 5 * STAGE_SAMPLE_CAP);
}

// ---------------------------------------------------------------------------
// The node-fail drill: fire within bound, clear after recovery, determinism
// ---------------------------------------------------------------------------

#[test]
fn node_fail_trips_burn_alert_within_bound_and_clears() {
    let spec = SloSpec::deployment_default(loose_budget_ms());
    let d = fail_drill(FleetConfig::default().des_seed, &spec);

    assert!(d.report.conserved());
    assert!(d.report.shed_failed > 0, "the calibrated kill must shed in-flight work");
    assert!(d.report.windows_reconcile());

    // fires within the detection bound around the kill window (sheds are
    // attributed at arrival, so allow the bound on both sides)
    let w_fail = (d.fail_at_s / d.window_s) as usize;
    let slack = spec.max_detection_windows();
    assert!(
        d.monitor.fires_within("availability", w_fail.saturating_sub(slack), 2 * slack),
        "availability burn alert must fire near window {w_fail}; alerts: {:?}",
        d.monitor.alerts.iter().map(|a| a.describe()).collect::<Vec<_>>()
    );
    // ...and every rule that fired has cleared by the end of the series
    assert!(
        d.monitor.cleared("availability"),
        "availability must clear after recovery; alerts: {:?}",
        d.monitor.alerts.iter().map(|a| a.describe()).collect::<Vec<_>>()
    );

    // bit-determinism: the identical scenario yields an identical monitor
    // report (series, spec, and alert stream compared structurally)
    assert_eq!(d.monitor, d.monitor2, "monitored rerun must be bit-identical");

    // monitoring off: the plain run's report is bit-identical
    assert_eq!(d.plain.completed, d.report.completed);
    assert_eq!(d.plain.shed, d.report.shed);
    assert_eq!(d.plain.shed_failed, d.report.shed_failed);
    assert_eq!(d.plain.qps.to_bits(), d.report.qps.to_bits());
    assert_eq!(d.plain.p50_ms.to_bits(), d.report.p50_ms.to_bits());
    assert_eq!(d.plain.p99_ms.to_bits(), d.report.p99_ms.to_bits());
    assert_eq!(d.plain.span_s.to_bits(), d.report.span_s.to_bits());
}

#[test]
fn burn_alert_lifecycle_holds_across_des_seeds() {
    // the drill is re-calibrated per seed (its own probe, t*, and window
    // width); detection and recovery must hold at each, and each must be
    // internally bit-deterministic
    let spec = SloSpec::deployment_default(loose_budget_ms());
    for des_seed in [FleetConfig::default().des_seed ^ 0x5EED, 7u64] {
        let d = fail_drill(des_seed, &spec);
        let w_fail = (d.fail_at_s / d.window_s) as usize;
        let slack = spec.max_detection_windows();
        assert!(
            d.monitor.fires_within("availability", w_fail.saturating_sub(slack), 2 * slack),
            "seed {des_seed:#x}: alert must fire near window {w_fail}; alerts: {:?}",
            d.monitor.alerts.iter().map(|a| a.describe()).collect::<Vec<_>>()
        );
        assert!(d.monitor.cleared("availability"), "seed {des_seed:#x}: alert must clear");
        assert_eq!(d.monitor, d.monitor2, "seed {des_seed:#x}: rerun must be bit-identical");
    }
}

// ---------------------------------------------------------------------------
// Bench regression gate, end to end off a real report
// ---------------------------------------------------------------------------

fn with_metric(mut doc: Json, key: &str, v: f64) -> Json {
    if let Json::Obj(m) = &mut doc {
        m.insert(key.to_string(), Json::num(v));
    }
    doc
}

#[test]
fn bench_diff_gates_injected_qps_regression() {
    let spec = SloSpec::deployment_default(loose_budget_ms());
    let d = fail_drill(FleetConfig::default().des_seed, &spec);
    let baseline = d
        .report
        .bench_report("monitor_drill", "sim")
        .accept("windows_conserve_totals", d.report.windows_reconcile())
        .to_json();
    let tol = compare::Tolerances::default();

    // identical fresh report passes
    let same = compare::compare(&baseline, &baseline, &tol).unwrap();
    assert!(same.pass(), "identical report must pass: {:?}", same.failures());

    // a 10% QPS drop (well past the 5% tolerance) fails the gate
    let qps = baseline.get("qps").and_then(Json::as_f64).unwrap();
    let slower = with_metric(baseline.clone(), "qps", qps * 0.90);
    let diff = compare::compare(&baseline, &slower, &tol).unwrap();
    assert!(!diff.pass(), "a 10% QPS regression must fail the gate");
    assert!(diff.failures().iter().any(|f| f.contains("qps")), "{:?}", diff.failures());

    // ...while a 10% improvement passes (direction-aware)
    let faster = with_metric(baseline.clone(), "qps", qps * 1.10);
    assert!(compare::compare(&baseline, &faster, &tol).unwrap().pass());
}

// ---------------------------------------------------------------------------
// Wall-clock tier: the streaming server feed reconciles too
// ---------------------------------------------------------------------------

#[test]
fn server_window_feed_reconciles_on_sim_backend() {
    let eng = engine();
    let batch = 16;
    let server = Arc::new(RecsysServer::new(eng.clone(), batch, "int8").unwrap());
    let mut gen = RecsysGen::from_manifest(9, batch, eng.manifest()).unwrap();
    let reqs: Vec<_> = (0..12).map(|_| gen.next()).collect();
    let opts =
        ServeOptions { workers: 1, window_s: Some(1e-4), ..ServeOptions::default() };

    let m = server.serve_with(reqs.clone(), &opts).unwrap();
    let s = m.windows.as_ref().expect("single-worker streaming path collects windows");
    assert_eq!(s.totals().completed as usize, m.completed);
    assert_eq!(s.totals().offered as usize, m.completed, "closed loop: offered == completed");
    assert!(s.windows > 0);

    // modeled clock: the series is deterministic across runs...
    let m2 = server.serve_with(reqs.clone(), &opts).unwrap();
    assert_eq!(m.windows, m2.windows, "modeled-clock window series must be deterministic");

    // ...and turning the feed off changes nothing observable
    let off = server
        .serve_with(reqs, &ServeOptions { workers: 1, ..ServeOptions::default() })
        .unwrap();
    assert!(off.windows.is_none());
    assert_eq!(off.completed, m.completed);
    assert_eq!(off.wall_s.to_bits(), m.wall_s.to_bits());
    assert_eq!(off.latency.p50().to_bits(), m.latency.p50().to_bits());
}
