"""AOT contract tests: manifest consistency + HLO text loadability markers."""

import json
import os

import pytest

from compile import aot
from compile.models import dlrm as dlrm_mod


ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_dtype_table_covers_manifest_dtypes():
    assert set(aot.DTYPES) >= {"f32", "i32", "i8"}


def test_lower_artifact_emits_hlo_text():
    cfg = dlrm_mod.DlrmConfig(num_tables=2, rows_per_table=50, embed_dim=8,
                              dense_in=16, bottom_mlp=(16, 8), top_mlp=(8, 1),
                              max_lookups=4)
    specs = dlrm_mod.sls_shard_specs(cfg, [0], batch=4)
    fn = dlrm_mod.make_sls_shard_fn(cfg, [0], batch=4)
    hlo, outs = aot.lower_artifact(fn, specs)
    assert hlo.startswith("HloModule")
    assert "ENTRY" in hlo
    assert outs[0]["shape"] == [4, 1, 8]
    assert outs[0]["dtype"] == "f32"


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_matches_files():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == 1
    names = set()
    for a in m["artifacts"]:
        assert a["name"] not in names, "duplicate artifact name"
        names.add(a["name"])
        path = os.path.join(ART_DIR, a["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule")
        kinds = {i["kind"] for i in a["inputs"]}
        assert kinds <= {"weight", "weight_q", "input"}
        # every artifact must have at least one request input
        assert any(i["kind"] == "input" for i in a["inputs"])
    assert "configs" in m and {"dlrm", "xlmr", "cv"} <= set(m["configs"])
