"""L2 model-level tests: shapes, finiteness, partition-vs-monolith equality,
quantized-vs-fp accuracy proxies (the paper's offline metrics, SV-A)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from compile.models import dlrm as dlrm_mod
from compile.models import xlmr as xlmr_mod
from compile.models import cv as cv_mod


SMALL_DLRM = dlrm_mod.DlrmConfig(
    num_tables=4, rows_per_table=200, embed_dim=16, dense_in=32,
    bottom_mlp=(32, 16), top_mlp=(32, 1), max_lookups=8)
SMALL_XLMR = xlmr_mod.XlmrConfig(layers=2, d_model=32, heads=4, ffn=64,
                                 vocab=100, max_pos=64)
SMALL_CV = cv_mod.CvConfig(image=16, stem_ch=8, stages=((8, 1), (16, 1)),
                           groups=4, classes=10)


def _make_args(specs, seed=0):
    r = np.random.default_rng(seed)
    args = []
    for (name, shape, dt, kind) in specs:
        if dt == "f32":
            args.append(jnp.asarray(r.normal(size=shape).astype(np.float32) * 0.1))
        elif dt == "i32":
            if name.startswith("idx"):
                args.append(jnp.asarray(r.integers(0, 200, size=shape).astype(np.int32)))
            elif name.startswith("len") or name == "pad_len":
                hi = shape[0] if name == "pad_len" else 9
                args.append(jnp.asarray(r.integers(1, 9, size=shape).astype(np.int32)))
            elif name == "ids":
                args.append(jnp.asarray(r.integers(0, 100, size=shape).astype(np.int32)))
            else:
                args.append(jnp.asarray(r.integers(0, 4, size=shape).astype(np.int32)))
        elif dt == "i8":
            args.append(jnp.asarray(r.integers(-127, 128, size=shape).astype(np.int8)))
        else:
            raise AssertionError(dt)
    return args


# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------

def test_dlrm_dense_fp32_shapes_and_range():
    cfg, b = SMALL_DLRM, 8
    specs = dlrm_mod.dense_specs(cfg, b, quantized=False)
    fn = dlrm_mod.make_dense_fn(cfg, b, quantized=False)
    (out,) = fn(*_make_args(specs))
    assert out.shape == (b, 1)
    o = np.asarray(out)
    assert np.all(np.isfinite(o)) and np.all(o >= 0) and np.all(o <= 1)


def test_dlrm_dense_int8_close_to_fp32():
    """Quantized dense partition tracks the fp32 one — the op-level proxy for
    the paper's <=0.05% NE budget."""
    cfg, b = SMALL_DLRM, 8
    specs_f = dlrm_mod.dense_specs(cfg, b, quantized=False)
    fn_f = dlrm_mod.make_dense_fn(cfg, b, quantized=False)
    args_f = _make_args(specs_f, seed=1)
    names_f = [s[0] for s in specs_f]
    p = dict(zip(names_f, args_f))

    # quantize the fp weights into the int8 spec ordering
    specs_q = dlrm_mod.dense_specs(cfg, b, quantized=True)
    args_q = []
    for (name, shape, dt, kind) in specs_q:
        if name.endswith(tuple(f"wq{i}" for i in range(4))):
            base = name.replace("wq", "w")
            pre = name.split("_")[0]
            i = name[-1]
            w = p[f"{pre}_w{i}"]
            wq, sc, zp = ref.quantize_rowwise_int8(w)
            args_q.append(wq)
        elif "scale" in name:
            pre, i = name.split("_scale")
            wq, sc, zp = ref.quantize_rowwise_int8(p[f"{pre}_w{i}"])
            args_q.append(sc)
        elif "zp" in name:
            pre, i = name.split("_zp")
            wq, sc, zp = ref.quantize_rowwise_int8(p[f"{pre}_w{i}"])
            args_q.append(zp)
        else:
            args_q.append(p[name])
    fn_q = dlrm_mod.make_dense_fn(cfg, b, quantized=True)
    (out_q,) = fn_q(*args_q)
    (out_f,) = fn_f(*args_f)
    err = np.max(np.abs(np.asarray(out_q) - np.asarray(out_f)))
    assert err < 0.05, err   # sigmoid outputs: 5e-2 absolute


def test_dlrm_shards_plus_dense_equals_monolith():
    """Partitioned execution (Fig. 6) must be numerically identical to the
    unpartitioned net: shard pooling -> concat == monolithic SLS."""
    cfg, b = SMALL_DLRM, 4
    r = np.random.default_rng(3)
    tables = [jnp.asarray(r.normal(size=(cfg.rows_per_table, cfg.embed_dim))
                          .astype(np.float32)) for _ in range(cfg.num_tables)]
    idx = [jnp.asarray(r.integers(0, cfg.rows_per_table,
                                  size=(b, cfg.max_lookups)).astype(np.int32))
           for _ in range(cfg.num_tables)]
    lens = [jnp.asarray(r.integers(0, cfg.max_lookups + 1, size=(b,))
                        .astype(np.int32)) for _ in range(cfg.num_tables)]

    # two shards of two tables each
    pooled = []
    for c in range(2):
        tl = [2 * c, 2 * c + 1]
        fn = dlrm_mod.make_sls_shard_fn(cfg, tl, b)
        args = [tables[t] for t in tl]
        for t in tl:
            args += [idx[t], lens[t]]
        (out,) = fn(*args)
        pooled.append(np.asarray(out))
    sharded = np.concatenate(pooled, axis=1)         # [b, 4, d]

    mono = np.stack([np.asarray(ref.sls(tables[t], idx[t], lens[t]))
                     for t in range(cfg.num_tables)], axis=1)
    np.testing.assert_allclose(sharded, mono, rtol=1e-5, atol=1e-5)


def test_dlrm_param_count_formula():
    cfg = SMALL_DLRM
    # tables + bottom (32*32+32 + 32*16+16) + top over interaction dim
    expect = 4 * 200 * 16
    d = 32
    for h in (32, 16):
        expect += d * h + h
        d = h
    d = cfg.interaction_dim
    for h in (32, 1):
        expect += d * h + h
        d = h
    assert cfg.param_count() == expect


def test_dlrm_interaction_dim():
    cfg = SMALL_DLRM  # 4 tables + dense = 5 features
    assert cfg.interaction_dim == 16 + 5 * 4 // 2


# ---------------------------------------------------------------------------
# XLM-R
# ---------------------------------------------------------------------------

def test_xlmr_shapes_and_finiteness():
    cfg, b, s = SMALL_XLMR, 2, 16
    specs = xlmr_mod.model_specs(cfg, b, s)
    fn = xlmr_mod.make_model_fn(cfg, b, s)
    args = _make_args(specs, seed=2)
    pooled, hidden = fn(*args)
    assert pooled.shape == (b, cfg.d_model)
    assert hidden.shape == (b, s, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(pooled)))


def test_xlmr_pad_invariance_of_pooled():
    """Padding a sentence to a larger bucket must not change the pooled
    embedding (the bucket-switching correctness requirement of SVI-A)."""
    cfg, b = SMALL_XLMR, 1
    r = np.random.default_rng(5)
    # same weights for both buckets
    specs16 = xlmr_mod.model_specs(cfg, b, 16)
    args16 = _make_args(specs16, seed=7)
    names = [s[0] for s in specs16]
    p16 = dict(zip(names, args16))
    true_len = 10
    ids16 = jnp.asarray(np.pad(r.integers(0, cfg.vocab, size=(1, true_len)),
                               ((0, 0), (0, 16 - true_len))).astype(np.int32))
    p16["ids"], p16["pad_len"] = ids16, jnp.asarray(np.array([true_len], np.int32))

    fn16 = xlmr_mod.make_model_fn(cfg, b, 16)
    pooled16, _ = fn16(*[p16[n] for n in names])

    specs32 = xlmr_mod.model_specs(cfg, b, 32)
    names32 = [s[0] for s in specs32]
    p32 = dict(p16)
    ids32 = jnp.asarray(np.pad(np.asarray(ids16), ((0, 0), (0, 16))).astype(np.int32))
    p32["ids"] = ids32
    fn32 = xlmr_mod.make_model_fn(cfg, b, 32)
    pooled32, _ = fn32(*[p32[n] for n in names32])

    # NOTE: padded positions do participate in attention (paper pads with a
    # pad token and tolerates it); pooled uses only valid positions. With a
    # nonzero pad embedding the result shifts slightly; require high cosine
    # similarity, the paper's own embedding-quality metric (>=98%, SV-A).
    a = np.asarray(pooled16)[0]
    bb = np.asarray(pooled32)[0]
    cos = float(np.dot(a, bb) / (np.linalg.norm(a) * np.linalg.norm(bb) + 1e-9))
    assert cos >= 0.98, cos


def test_xlmr_param_count_positive():
    assert SMALL_XLMR.param_count() > 0
    assert xlmr_mod.XlmrConfig().param_count() > 3_000_000


# ---------------------------------------------------------------------------
# CV
# ---------------------------------------------------------------------------

def test_cv_shapes_and_finiteness():
    cfg, b = SMALL_CV, 2
    specs = cv_mod.model_specs(cfg, b)
    fn = cv_mod.make_model_fn(cfg, b)
    logits, emb = fn(*_make_args(specs, seed=4))
    assert logits.shape == (b, cfg.classes)
    assert emb.shape[0] == b
    assert np.all(np.isfinite(np.asarray(logits)))


def test_cv_batch_consistency():
    """Running batch=2 equals two batch=1 runs (data-parallel correctness)."""
    cfg = SMALL_CV
    specs1 = cv_mod.model_specs(cfg, 1)
    specs2 = cv_mod.model_specs(cfg, 2)
    args2 = _make_args(specs2, seed=6)
    names = [s[0] for s in specs2]
    p = dict(zip(names, args2))
    fn2 = cv_mod.make_model_fn(cfg, 2)
    logits2, _ = fn2(*args2)

    fn1 = cv_mod.make_model_fn(cfg, 1)
    outs = []
    for i in range(2):
        p1 = dict(p)
        p1["image"] = p["image"][i:i + 1]
        outs.append(np.asarray(fn1(*[p1[n] for n in names])[0]))
    np.testing.assert_allclose(np.asarray(logits2),
                               np.concatenate(outs, 0), rtol=2e-4, atol=2e-5)


def test_cv_param_count_positive():
    assert SMALL_CV.param_count() > 0
