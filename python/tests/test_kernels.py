"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes/dtypes per the session contract; assert_allclose
against ref. These are the python half of the paper's SV-C numerics
validation story (the rust half lives in fbia::numerics).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sls import sls as pallas_sls, sls_vmem_bytes
from compile.kernels.quant_fc import (
    quant_fc as pallas_quant_fc, quant_fc_vmem_bytes, quant_fc_mxu_utilization)
from compile.kernels.attention import attention as pallas_attention, attention_vmem_bytes

SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# SLS
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    batch=st.integers(1, 33),
    max_len=st.integers(1, 24),
    rows=st.integers(4, 300),
    dim=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_sls_matches_ref(batch, max_len, rows, dim, seed):
    r = _rng(seed)
    table = jnp.asarray(r.normal(size=(rows, dim)).astype(np.float32))
    idx = jnp.asarray(r.integers(0, rows, size=(batch, max_len)).astype(np.int32))
    lens = jnp.asarray(r.integers(0, max_len + 1, size=(batch,)).astype(np.int32))
    got = pallas_sls(table, idx, lens)
    want = ref.sls(table, idx, lens)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sls_zero_lengths_give_zero():
    table = jnp.ones((10, 4), jnp.float32)
    idx = jnp.zeros((3, 5), jnp.int32)
    lens = jnp.zeros((3,), jnp.int32)
    assert np.all(np.asarray(pallas_sls(table, idx, lens)) == 0.0)


def test_sls_masked_tail_ignored():
    """Garbage in the padded index tail must not change the result (the
    partial-tensor contract of SVI-C)."""
    r = _rng(7)
    table = jnp.asarray(r.normal(size=(50, 8)).astype(np.float32))
    idx = jnp.asarray(r.integers(0, 50, size=(4, 6)).astype(np.int32))
    lens = jnp.asarray(np.array([2, 0, 6, 3], np.int32))
    base = np.asarray(pallas_sls(table, idx, lens))
    garbage = idx.at[:, 4:].set(49)  # clobber tail beyond all lens<=4 rows
    lens2 = jnp.asarray(np.array([2, 0, 4, 3], np.int32))
    got1 = np.asarray(pallas_sls(table, idx, lens2))
    got2 = np.asarray(pallas_sls(garbage, lens=lens2, indices=garbage) if False else
                      pallas_sls(table, garbage, lens2))
    np.testing.assert_allclose(got1, got2, rtol=1e-6)
    del base


def test_sls_weighted_ref_consistency():
    """Weighted SLS with unit weights equals plain SLS."""
    r = _rng(3)
    table = jnp.asarray(r.normal(size=(20, 6)).astype(np.float32))
    idx = jnp.asarray(r.integers(0, 20, size=(5, 4)).astype(np.int32))
    lens = jnp.asarray(r.integers(0, 5, size=(5,)).astype(np.int32))
    w = jnp.ones((5, 4), jnp.float32)
    np.testing.assert_allclose(ref.sls_weighted(table, idx, lens, w),
                               ref.sls(table, idx, lens), rtol=1e-6)


def test_sls_vmem_estimate_positive_monotone():
    a = sls_vmem_bytes(8, 32, 1000, 64)
    b = sls_vmem_bytes(16, 32, 1000, 64)
    assert 0 < a < b


# ---------------------------------------------------------------------------
# quant FC
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 96),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_fc_matches_ref(m, k, n, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(n, k)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    wq, sc, zp = ref.quantize_rowwise_int8(w)
    got = pallas_quant_fc(x, wq, sc, zp, b)
    want = ref.quant_fc(x, wq, sc, zp, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 16),
    k=st.integers(8, 64),
    n=st.integers(8, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_fc_close_to_fp32(m, k, n, seed):
    """int8 quantization error stays within the coarse bound expected from
    8-bit row-wise weights (paper: NE impact 0.02-0.05%; here we check the
    raw op-level error scale)."""
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(n, k)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(n,)).astype(np.float32))
    wq, sc, zp = ref.quantize_rowwise_int8(w)
    got = np.asarray(pallas_quant_fc(x, wq, sc, zp, b))
    fp = np.asarray(ref.fc(x, w, b))
    # error grows ~sqrt(k); allow generous constant
    bound = 0.05 * np.sqrt(k) * np.abs(x).max() + 1e-3
    assert np.max(np.abs(got - fp)) < bound, (np.max(np.abs(got - fp)), bound)


def test_quantize_roundtrip_error_bound():
    r = _rng(11)
    w = jnp.asarray(r.normal(size=(37, 53)).astype(np.float32))
    wq, sc, zp = ref.quantize_rowwise_int8(w)
    deq = np.asarray(ref.dequantize_rowwise_int8(wq, sc, zp))
    err = np.abs(deq - np.asarray(w))
    assert np.max(err / np.asarray(sc)[:, None]) <= 0.75  # within ~half an LSB
    assert wq.dtype == jnp.int8


def test_quant_fc_vmem_and_mxu_estimates():
    assert quant_fc_vmem_bytes(16, 64, 256) > 0
    assert 0 < quant_fc_mxu_utilization(16, 64, 256) <= 1.0
    assert quant_fc_mxu_utilization(128, 128, 128) == 1.0


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    h=st.integers(1, 8),
    s=st.sampled_from([8, 16, 32, 64]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(h, s, d, seed):
    r = _rng(seed)
    q = jnp.asarray(r.normal(size=(h, s, d)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(h, s, d)).astype(np.float32))
    v = jnp.asarray(r.normal(size=(h, s, d)).astype(np.float32))
    got = pallas_attention(q, k, v)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_odd_seq_falls_back_to_single_block():
    r = _rng(5)
    q = jnp.asarray(r.normal(size=(2, 33, 8)).astype(np.float32))
    got = pallas_attention(q, q, q)
    want = ref.attention(q, q, q)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_rows_sum_property():
    """softmax(scores) rows sum to 1 => attention of constant V returns V."""
    r = _rng(9)
    q = jnp.asarray(r.normal(size=(3, 16, 8)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(3, 16, 8)).astype(np.float32))
    v = jnp.ones((3, 16, 8), jnp.float32) * 2.5
    got = np.asarray(pallas_attention(q, k, v))
    np.testing.assert_allclose(got, 2.5 * np.ones_like(got), rtol=1e-5)


def test_attention_vmem_estimate():
    assert attention_vmem_bytes(32, 128, 32) > 0


# ---------------------------------------------------------------------------
# misc reference ops
# ---------------------------------------------------------------------------

def test_layernorm_zero_mean_unit_var():
    r = _rng(2)
    x = jnp.asarray(r.normal(size=(4, 32)).astype(np.float32))
    g = jnp.ones((32,), jnp.float32)
    b = jnp.zeros((32,), jnp.float32)
    y = np.asarray(ref.layernorm(x, g, b))
    np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.var(axis=-1), 1.0, atol=1e-3)


def test_gelu_fixed_points():
    x = jnp.asarray(np.array([0.0, 10.0, -10.0], np.float32))
    y = np.asarray(ref.gelu(x))
    np.testing.assert_allclose(y[0], 0.0, atol=1e-7)
    np.testing.assert_allclose(y[1], 10.0, rtol=1e-4)
    np.testing.assert_allclose(y[2], 0.0, atol=1e-3)


def test_dot_interaction_shape_and_symmetry():
    r = _rng(4)
    dense = jnp.asarray(r.normal(size=(3, 8)).astype(np.float32))
    sparse = jnp.asarray(r.normal(size=(3, 5, 8)).astype(np.float32))
    out = np.asarray(ref.dot_interaction(dense, sparse))
    f = 6
    assert out.shape == (3, 8 + f * (f - 1) // 2)
    # first d columns are the dense passthrough
    np.testing.assert_allclose(out[:, :8], np.asarray(dense), rtol=1e-6)
