"""L2 model: mini XLM-R (24-layer in the paper; configurable here), SII-C.

The paper serves XLM-R with static-shape buckets (32/64/128/512 tokens,
SVI-A): one compiled network per bucket, host-side padding picks the bucket.
We emit exactly that artifact family. The attention hot loop is the L1
Pallas kernel; everything else is plain jnp that XLA fuses.

The token-embedding step runs on-device too (the paper notes "additional
optimizations enable the embedding step ... on the accelerator as well").
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels import ref
from ..kernels.attention import attention as pallas_attention


@dataclass(frozen=True)
class XlmrConfig:
    layers: int = 4
    d_model: int = 256
    heads: int = 8
    ffn: int = 1024
    vocab: int = 8_000
    max_pos: int = 512

    @property
    def head_dim(self) -> int:
        return self.d_model // self.heads

    def param_count(self) -> int:
        per_layer = (4 * self.d_model * self.d_model + 4 * self.d_model  # qkv+o
                     + 2 * self.d_model * self.ffn + self.ffn + self.d_model
                     + 4 * self.d_model)  # two layernorms
        return (self.vocab * self.d_model + self.max_pos * self.d_model
                + self.layers * per_layer + 2 * self.d_model)


def layer_param_specs(cfg: XlmrConfig, l: int) -> list:
    d, f = cfg.d_model, cfg.ffn
    p = f"l{l}_"
    return [
        (p + "wq", (d, d), "f32", "weight"), (p + "bq", (d,), "f32", "weight"),
        (p + "wk", (d, d), "f32", "weight"), (p + "bk", (d,), "f32", "weight"),
        (p + "wv", (d, d), "f32", "weight"), (p + "bv", (d,), "f32", "weight"),
        (p + "wo", (d, d), "f32", "weight"), (p + "bo", (d,), "f32", "weight"),
        (p + "ln1_g", (d,), "f32", "weight"), (p + "ln1_b", (d,), "f32", "weight"),
        (p + "w1", (f, d), "f32", "weight"), (p + "b1", (f,), "f32", "weight"),
        (p + "w2", (d, f), "f32", "weight"), (p + "b2", (d,), "f32", "weight"),
        (p + "ln2_g", (d,), "f32", "weight"), (p + "ln2_b", (d,), "f32", "weight"),
    ]


def model_specs(cfg: XlmrConfig, batch: int, seq: int) -> list:
    specs = [
        ("tok_emb", (cfg.vocab, cfg.d_model), "f32", "weight"),
        ("pos_emb", (cfg.max_pos, cfg.d_model), "f32", "weight"),
        ("ln_f_g", (cfg.d_model,), "f32", "weight"),
        ("ln_f_b", (cfg.d_model,), "f32", "weight"),
    ]
    for l in range(cfg.layers):
        specs += layer_param_specs(cfg, l)
    specs.append(("ids", (batch, seq), "i32", "input"))
    specs.append(("pad_len", (batch,), "i32", "input"))  # true lengths
    return specs


def _encoder_layer(x, p, prefix, cfg: XlmrConfig, mask):
    """Pre-LN transformer encoder layer; attention via the Pallas kernel."""
    b, s, d = x.shape
    h, hd = cfg.heads, cfg.head_dim

    y = ref.layernorm(x, p[prefix + "ln1_g"], p[prefix + "ln1_b"])
    flat = y.reshape(b * s, d)
    q = (flat @ p[prefix + "wq"].T + p[prefix + "bq"]).reshape(b, s, h, hd)
    k = (flat @ p[prefix + "wk"].T + p[prefix + "bk"]).reshape(b, s, h, hd)
    v = (flat @ p[prefix + "wv"].T + p[prefix + "bv"]).reshape(b, s, h, hd)
    # fold batch into heads for the [H, S, D] pallas kernel contract
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    # mask padded keys by pushing them to -inf *before* the kernel: encode the
    # mask into k by zeroing and into an additive bias folded into v=0 rows.
    # Short padded buckets tolerate the simpler approach the paper uses:
    # padded tokens attend/are attended, then get dropped by the pooling mask.
    att = pallas_attention(qh, kh, vh)
    att = att.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b * s, d)
    o = att @ p[prefix + "wo"].T + p[prefix + "bo"]
    x = x + o.reshape(b, s, d)

    y = ref.layernorm(x, p[prefix + "ln2_g"], p[prefix + "ln2_b"])
    flat = y.reshape(b * s, d)
    hdn = ref.gelu(flat @ p[prefix + "w1"].T + p[prefix + "b1"])
    o = hdn @ p[prefix + "w2"].T + p[prefix + "b2"]
    return x + o.reshape(b, s, d)


def make_model_fn(cfg: XlmrConfig, batch: int, seq: int):
    """Returns fn(*args) -> (pooled [batch, d_model], hidden [batch, seq, d_model]).

    Pooled output is the mean over *valid* (non-pad) positions — the
    embedding the paper feeds to downstream classifiers (cosine-sim metric).
    """
    names = [s[0] for s in model_specs(cfg, batch, seq)]

    def fn(*args):
        p = dict(zip(names, args))
        ids, pad_len = p["ids"], p["pad_len"]
        x = p["tok_emb"][ids] + p["pos_emb"][:seq][None, :, :]
        mask = (jnp.arange(seq)[None, :] < pad_len[:, None])       # [B, S]
        for l in range(cfg.layers):
            x = _encoder_layer(x, p, f"l{l}_", cfg, mask)
        x = ref.layernorm(x, p["ln_f_g"], p["ln_f_b"])
        mf = mask.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(mf, axis=1, keepdims=True), 1.0)
        pooled = jnp.sum(x * mf[:, :, None], axis=1) / denom
        return (pooled, x)

    return fn
