"""L2 model: CV classification trunk (ResNeXt/RegNet-style), SII-B.

A small bottleneck CNN that preserves the paper's CV op mix: 1x1 pointwise
convs + 3x3 *grouped* convs (the channelwise/groupwise pattern Table II
shows dominating ResNeXt/RegNetY/FBNetV3), residual adds, global average
pooling, and a final FC. Convolutions use lax.conv_general_dilated at L2 --
XLA's fusion is the analogue of the vendor compiler's Conv_Add fusion.

Artifacts are emitted at batch {1, 4}, which feeds the paper's batching
ablation (SVI-B: batch 1 -> 4 gives 1.6-1.8x on the concept trunk).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels import ref


@dataclass(frozen=True)
class CvConfig:
    image: int = 64
    stem_ch: int = 32
    stages: tuple = ((32, 2), (64, 2), (128, 2))   # (channels, blocks)
    groups: int = 8
    classes: int = 100

    def param_count(self) -> int:
        n = 3 * 3 * 3 * self.stem_ch + self.stem_ch
        cin = self.stem_ch
        for ch, blocks in self.stages:
            for b in range(blocks):
                n += cin * ch + ch                       # 1x1 in
                n += 3 * 3 * (ch // self.groups) * ch + ch  # 3x3 grouped
                n += ch * ch + ch                        # 1x1 out
                if cin != ch:
                    n += cin * ch + ch                   # projection
                cin = ch
        n += cin * self.classes + self.classes
        return n


def _conv_specs(name, kh, kw, cin, cout):
    return [(f"{name}_w", (kh, kw, cin, cout), "f32", "weight"),
            (f"{name}_b", (cout,), "f32", "weight")]


def model_specs(cfg: CvConfig, batch: int) -> list:
    specs = _conv_specs("stem", 3, 3, 3, cfg.stem_ch)
    cin = cfg.stem_ch
    for si, (ch, blocks) in enumerate(cfg.stages):
        for bi in range(blocks):
            p = f"s{si}b{bi}"
            specs += _conv_specs(p + "_pw1", 1, 1, cin, ch)
            specs += _conv_specs(p + "_gw", 3, 3, ch // cfg.groups, ch)
            specs += _conv_specs(p + "_pw2", 1, 1, ch, ch)
            if cin != ch:
                specs += _conv_specs(p + "_proj", 1, 1, cin, ch)
            cin = ch
    specs += [("head_w", (cfg.classes, cin), "f32", "weight"),
              ("head_b", (cfg.classes,), "f32", "weight")]
    specs.append(("image", (batch, cfg.image, cfg.image, 3), "f32", "input"))
    return specs


def _conv(x, w, b, stride=1, groups=1):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return y + b[None, None, None, :]


def make_model_fn(cfg: CvConfig, batch: int):
    """Returns fn(*args) -> (logits [batch, classes], embedding [batch, C]).

    The embedding output mirrors the paper's "backbone models that only
    produce embeddings" whose quality gate is cosine similarity (SV-A).
    """
    names = [s[0] for s in model_specs(cfg, batch)]

    def fn(*args):
        p = dict(zip(names, args))
        x = p["image"]
        x = jax.nn.relu(_conv(x, p["stem_w"], p["stem_b"], stride=2))
        cin = cfg.stem_ch
        for si, (ch, blocks) in enumerate(cfg.stages):
            for bi in range(blocks):
                pre = f"s{si}b{bi}"
                stride = 2 if (bi == 0 and si > 0) else 1
                y = jax.nn.relu(_conv(x, p[pre + "_pw1_w"], p[pre + "_pw1_b"]))
                y = jax.nn.relu(_conv(y, p[pre + "_gw_w"], p[pre + "_gw_b"],
                                      stride=stride, groups=cfg.groups))
                y = _conv(y, p[pre + "_pw2_w"], p[pre + "_pw2_b"])
                if cin != ch or stride != 1:
                    sc = _conv(x, p[pre + "_proj_w"], p[pre + "_proj_b"],
                               stride=stride) if pre + "_proj_w" in p else x
                    x = jax.nn.relu(y + sc)
                else:
                    x = jax.nn.relu(y + x)
                cin = ch
        emb = jnp.mean(x, axis=(1, 2))                    # global avg pool
        logits = ref.fc(emb, p["head_w"], p["head_b"])
        return (logits, emb)

    return fn
