"""L2 model: DLRM (Deep Learning Recommendation Model), SII-A / Fig. 2.

The model is split exactly along the paper's multi-card partitioning scheme
(SVI-B, Fig. 6): the *SLS partition* (embedding-table shards, model
parallel) and the *dense partition* (bottom MLP + dot interaction + top MLP,
data parallel) are lowered as separate HLO artifacts. The Rust coordinator
pipelines them across requests.

Weights are HLO *parameters* (not baked constants): the coordinator
generates them deterministically, uploads them once per card as
device-resident buffers, and feeds only the request tensors per inference --
matching the paper's device-resident-tensor optimization (SVI-C).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..kernels import ref
from ..kernels.sls import sls as pallas_sls
from ..kernels.quant_fc import quant_fc as pallas_quant_fc


@dataclass(frozen=True)
class DlrmConfig:
    """Mini-DLRM sized to keep artifacts fast to build while preserving the
    paper's op mix (SLS + FC dominated, Table II column 1)."""
    num_tables: int = 8
    rows_per_table: int = 25_000
    embed_dim: int = 64
    dense_in: int = 256
    bottom_mlp: tuple = (256, 128, 64)   # last must equal embed_dim
    top_mlp: tuple = (512, 256, 1)
    max_lookups: int = 32                # static upper bound (partial tensors)

    @property
    def interaction_dim(self) -> int:
        f = self.num_tables + 1
        return self.embed_dim + f * (f - 1) // 2

    def param_count(self) -> int:
        n = self.num_tables * self.rows_per_table * self.embed_dim
        d = self.dense_in
        for h in self.bottom_mlp:
            n += d * h + h
            d = h
        d = self.interaction_dim
        for h in self.top_mlp:
            n += d * h + h
            d = h
        return n


# ---------------------------------------------------------------------------
# Parameter specs — shared contract with the Rust weight generator
# ---------------------------------------------------------------------------

def mlp_param_specs(prefix: str, d_in: int, widths: tuple) -> list:
    specs = []
    d = d_in
    for i, h in enumerate(widths):
        specs.append((f"{prefix}_w{i}", (h, d), "f32", "weight"))
        specs.append((f"{prefix}_b{i}", (h,), "f32", "weight"))
        d = h
    return specs


def mlp_param_specs_int8(prefix: str, d_in: int, widths: tuple) -> list:
    specs = []
    d = d_in
    for i, h in enumerate(widths):
        specs.append((f"{prefix}_wq{i}", (h, d), "i8", "weight_q"))
        specs.append((f"{prefix}_scale{i}", (h,), "f32", "weight"))
        specs.append((f"{prefix}_zp{i}", (h,), "f32", "weight"))
        specs.append((f"{prefix}_b{i}", (h,), "f32", "weight"))
        d = h
    return specs


def _mlp_fp32(x, params, prefix, widths, final_act):
    for i in range(len(widths)):
        w = params[f"{prefix}_w{i}"]
        b = params[f"{prefix}_b{i}"]
        x = ref.fc(x, w, b)
        if i < len(widths) - 1 or final_act == "relu":
            x = jax.nn.relu(x)
    return x


def _mlp_int8(x, params, prefix, widths, final_act):
    for i in range(len(widths)):
        x = pallas_quant_fc(
            x,
            params[f"{prefix}_wq{i}"],
            params[f"{prefix}_scale{i}"],
            params[f"{prefix}_zp{i}"],
            params[f"{prefix}_b{i}"],
        )
        if i < len(widths) - 1 or final_act == "relu":
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# SLS partition (model-parallel shard)
# ---------------------------------------------------------------------------

def sls_shard_specs(cfg: DlrmConfig, tables: list, batch: int) -> list:
    """Input specs for one SLS shard artifact: tables (weights) then the
    per-table request tensors (indices + lengths)."""
    specs = []
    for t in tables:
        specs.append((f"table{t}", (cfg.rows_per_table, cfg.embed_dim), "f32", "weight"))
    for t in tables:
        specs.append((f"idx{t}", (batch, cfg.max_lookups), "i32", "input"))
        specs.append((f"len{t}", (batch,), "i32", "input"))
    return specs


def make_sls_shard_fn(cfg: DlrmConfig, tables: list, batch: int):
    """Returns fn(*args) -> ([batch, len(tables), dim],) pooling each table.

    Uses the L1 Pallas SLS kernel so the kernel lowers into this artifact.
    """
    n = len(tables)

    def fn(*args):
        tbls = args[:n]
        pooled = []
        for i in range(n):
            idx = args[n + 2 * i]
            lens = args[n + 2 * i + 1]
            pooled.append(pallas_sls(tbls[i], idx, lens))
        return (jnp.stack(pooled, axis=1),)   # [B, n, D]

    return fn


# ---------------------------------------------------------------------------
# Dense partition (data-parallel replica)
# ---------------------------------------------------------------------------

def dense_specs(cfg: DlrmConfig, batch: int, quantized: bool) -> list:
    mk = mlp_param_specs_int8 if quantized else mlp_param_specs
    specs = []
    specs += mk("bot", cfg.dense_in, cfg.bottom_mlp)
    specs += mk("top", cfg.interaction_dim, cfg.top_mlp)
    specs.append(("dense", (batch, cfg.dense_in), "f32", "input"))
    specs.append(("sparse", (batch, cfg.num_tables, cfg.embed_dim), "f32", "input"))
    return specs


def make_dense_fn(cfg: DlrmConfig, batch: int, quantized: bool):
    """Returns fn(*args) -> ([batch, 1] sigmoid score,).

    args follow dense_specs order: MLP params then dense/sparse inputs.
    The int8 variant runs both MLPs through the L1 quant_fc Pallas kernel,
    mirroring the paper's int8 FC deployment with fp32 interaction.
    """
    names = [s[0] for s in dense_specs(cfg, batch, quantized)]
    mlp = _mlp_int8 if quantized else _mlp_fp32

    def fn(*args):
        params = dict(zip(names, args))
        dense, sparse = params["dense"], params["sparse"]
        bot = mlp(dense, params, "bot", cfg.bottom_mlp, "relu")
        inter = ref.dot_interaction(bot, sparse)
        # paper SV-B: the *last* FC stays high precision; our int8 MLP keeps
        # the final layer's epilogue in fp32 which carries the logit.
        logit = mlp(inter, params, "top", cfg.top_mlp, "none")
        return (jax.nn.sigmoid(logit),)

    return fn


# ---------------------------------------------------------------------------
# Monolithic reference (for tests / single-card serving)
# ---------------------------------------------------------------------------

def make_monolithic_fn(cfg: DlrmConfig, batch: int):
    """Full DLRM in one graph (reference / single-card path): SLS over all
    tables + dense partition, fp32."""
    n = cfg.num_tables

    def fn(*args):
        # args: tables[n], (idx, len)*n, mlp params..., dense
        tbls = args[:n]
        pooled = []
        for i in range(n):
            pooled.append(ref.sls(tbls[i], args[n + 2 * i], args[n + 2 * i + 1]))
        sparse = jnp.stack(pooled, axis=1)
        rest = args[3 * n:]
        names = [s[0] for s in mlp_param_specs("bot", cfg.dense_in, cfg.bottom_mlp)]
        names += [s[0] for s in mlp_param_specs("top", cfg.interaction_dim, cfg.top_mlp)]
        params = dict(zip(names, rest[:-1]))
        dense = rest[-1]
        bot = _mlp_fp32(dense, params, "bot", cfg.bottom_mlp, "relu")
        inter = ref.dot_interaction(bot, sparse)
        logit = _mlp_fp32(inter, params, "top", cfg.top_mlp, "none")
        return (jax.nn.sigmoid(logit),)

    return fn
