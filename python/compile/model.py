"""Facade: re-export the L2 model families (see models/)."""
from .models.dlrm import DlrmConfig, make_dense_fn, make_sls_shard_fn, make_monolithic_fn  # noqa: F401
from .models.xlmr import XlmrConfig, make_model_fn as make_xlmr_fn  # noqa: F401
from .models.cv import CvConfig, make_model_fn as make_cv_fn  # noqa: F401
