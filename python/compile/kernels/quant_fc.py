"""L1 Pallas kernel: row-wise int8 quantized fully-connected layer.

Hardware adaptation (DESIGN.md S3): the paper's Matrix Engine computes
int8 x int8 -> int32 GEMMs. The TPU analogue is the MXU with an int8 matmul
contraction accumulated in int32, tiled (M, N) with the full K dimension
resident per tile (the FC weights of interest are tens of MB and row-major;
K-resident tiles make the epilogue a pure per-tile op). The float epilogue
(zero-point correction, per-output-channel scale, bias) runs on the vector
unit, fused -- mirroring the paper's Dequantize fusion remarks (SV-C).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_M = 16
DEFAULT_BLOCK_N = 64


def _quant_fc_kernel(xq_ref, rowsum_ref, wq_ref, scale_ref, zp_ref, bias_ref,
                     o_ref, *, xs_inv: None):
    """One (m, n) tile: int32 GEMM + fused dequant epilogue.

    xq_ref:     [bm, k] i8 quantized activations
    rowsum_ref: [bm] f32 per-row activation sums (zero-point correction)
    wq_ref:     [bn, k] i8 row-wise quantized weights
    scale_ref:  [bn] f32, zp_ref: [bn] f32, bias_ref: [bn] f32
    o_ref:      [bm, bn] f32  (scale includes the activation scale already)
    """
    xq = xq_ref[...].astype(jnp.int32)
    wq = wq_ref[...].astype(jnp.int32)
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)              # [bm, bn] int32 (MXU)
    acc_f = acc.astype(jnp.float32)
    acc_f = acc_f + rowsum_ref[...][:, None] * zp_ref[...][None, :]
    o_ref[...] = acc_f * scale_ref[...][None, :] + bias_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def quant_fc(x: jax.Array, wq: jax.Array, scale: jax.Array, zp: jax.Array,
             bias: jax.Array, block_m: int = DEFAULT_BLOCK_M,
             block_n: int = DEFAULT_BLOCK_N) -> jax.Array:
    """y ~= x @ dequant(wq)^T + bias with integer GEMM.

    x: [m, k] f32; wq: [n, k] i8; scale/zp/bias: [n] f32.
    Dynamic symmetric per-tensor activation quantization happens outside the
    grid (it needs a global absmax), then the integer GEMM is tiled.
    """
    m, k = x.shape
    n, k2 = wq.shape
    assert k == k2, f"K mismatch {k} vs {k2}"

    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    xs = absmax / 127.0
    xq = jnp.clip(jnp.round(x / xs), -127, 127).astype(jnp.int8)
    rowsum = jnp.sum(xq.astype(jnp.int32), axis=1).astype(jnp.float32)

    # fold the activation scale into the per-channel weight scale
    eff_scale = scale * xs

    pad_m = (-m) % block_m
    pad_n = (-n) % block_n
    if pad_m or pad_n:
        xq_p = jnp.pad(xq, ((0, pad_m), (0, 0)))
        rs_p = jnp.pad(rowsum, (0, pad_m))
        wq_p = jnp.pad(wq, ((0, pad_n), (0, 0)))
        sc_p = jnp.pad(eff_scale, (0, pad_n))
        zp_p = jnp.pad(zp, (0, pad_n))
        b_p = jnp.pad(bias, (0, pad_n))
    else:
        xq_p, rs_p, wq_p, sc_p, zp_p, b_p = xq, rowsum, wq, eff_scale, zp, bias

    mp, np_ = m + pad_m, n + pad_n
    grid = (mp // block_m, np_ // block_n)
    out = pl.pallas_call(
        functools.partial(_quant_fc_kernel, xs_inv=None),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xq_p, rs_p, wq_p, sc_p, zp_p, b_p)
    return out[:m, :n]


def quant_fc_vmem_bytes(block_m: int, block_n: int, k: int) -> int:
    """Static per-tile VMEM footprint (DESIGN.md S8): activation tile +
    weight tile + int32 accumulator + epilogue vectors."""
    return (block_m * k          # xq tile (i8)
            + block_n * k        # wq tile (i8)
            + block_m * block_n * 4   # acc (i32)
            + block_m * block_n * 4   # out (f32)
            + block_m * 4 + 3 * block_n * 4)


def quant_fc_mxu_utilization(block_m: int, block_n: int, k: int,
                             mxu_dim: int = 128) -> float:
    """Fraction of MXU lanes busy for one tile: the systolic array processes
    mxu_dim x mxu_dim tiles; partial tiles waste lanes."""
    eff_m = block_m / (((block_m + mxu_dim - 1) // mxu_dim) * mxu_dim)
    eff_n = block_n / (((block_n + mxu_dim - 1) // mxu_dim) * mxu_dim)
    eff_k = min(k / mxu_dim, 1.0) if k < mxu_dim else 1.0
    return eff_m * eff_n * eff_k
