"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the "numeric reference implementations" of the paper's SV-C: every
kernel that runs on the accelerator (here: lowered through Pallas) has an
independent, easily-auditable implementation that pytest compares against.
The Rust side (`fbia::numerics`) re-implements the same math a third time so
release-over-release validation can run with no Python at all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# SparseLengthsSum (EmbeddingBag) - SII-A
# ---------------------------------------------------------------------------

def sls(table: jax.Array, indices: jax.Array, lengths: jax.Array) -> jax.Array:
    """Sum-pool `lengths[b]` rows of `table` per batch element.

    table:   [rows, dim] f32
    indices: [batch, max_len] i32 -- only the first lengths[b] entries of row
             b are valid; the rest may be arbitrary (they are masked, matching
             the paper's "partial tensor" semantics where the tail of the
             statically-shaped index tensor is unused).
    lengths: [batch] i32
    returns: [batch, dim] f32
    """
    batch, max_len = indices.shape
    gathered = table[indices]                                   # [B, L, D]
    mask = (jnp.arange(max_len)[None, :] < lengths[:, None])    # [B, L]
    return jnp.sum(gathered * mask[:, :, None].astype(table.dtype), axis=1)


def sls_weighted(table: jax.Array, indices: jax.Array, lengths: jax.Array,
                 weights: jax.Array) -> jax.Array:
    """SparseLengthsWeightedSum: per-lookup scalar weights."""
    batch, max_len = indices.shape
    gathered = table[indices]                                   # [B, L, D]
    mask = (jnp.arange(max_len)[None, :] < lengths[:, None])
    w = weights * mask.astype(table.dtype)
    return jnp.sum(gathered * w[:, :, None], axis=1)


# ---------------------------------------------------------------------------
# Row-wise int8 quantization + quantized FC - SV-B
# ---------------------------------------------------------------------------

def quantize_rowwise_int8(w: jax.Array):
    """Asymmetric per-row (output-channel) int8 quantization of [out, in]
    weights. Returns (q int8 [out,in], scale f32 [out], zp f32 [out]) where a
    stored value v reconstructs as (v - zp) * scale.

    Matches the Caffe2/FBGEMM row-wise scheme the paper deploys.
    """
    w = w.astype(jnp.float32)
    wmin = jnp.minimum(jnp.min(w, axis=1), 0.0)
    wmax = jnp.maximum(jnp.max(w, axis=1), 0.0)
    scale = jnp.maximum((wmax - wmin) / 255.0, 1e-8)
    zp = jnp.round(wmin / scale) + 128.0          # in [-? .. 128], f32
    q = jnp.clip(jnp.round(w / scale[:, None] - zp[:, None]), -128, 127)
    return q.astype(jnp.int8), scale, zp


def dequantize_rowwise_int8(q: jax.Array, scale: jax.Array, zp: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) + zp[:, None]) * scale[:, None]


def quant_fc(x: jax.Array, wq: jax.Array, scale: jax.Array, zp: jax.Array,
             bias: jax.Array) -> jax.Array:
    """Quantized FC: y ~= x @ dequant(wq)^T + bias, computed as an integer
    matmul with a float epilogue (the accelerator Matrix Engine formulation).

    x: [m, k] f32. Activations are quantized dynamically (symmetric,
       per-tensor) as in the paper's SVIII "dynamic quantization" remark.
    wq: [n, k] int8 row-wise quantized weights; scale/zp: [n] f32.
    """
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    xs = absmax / 127.0
    xq = jnp.clip(jnp.round(x / xs), -127, 127).astype(jnp.int8)
    # integer GEMM accumulated in int32
    acc = jnp.matmul(xq.astype(jnp.int32), wq.astype(jnp.int32).T)
    # epilogue: add zero-point contribution, apply scales, add bias
    row_sums = jnp.sum(xq.astype(jnp.int32), axis=1).astype(jnp.float32)  # [m]
    acc_f = acc.astype(jnp.float32) + row_sums[:, None] * zp[None, :]
    return acc_f * (xs * scale)[None, :] + bias[None, :]


def fc(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Plain fp32 FC used as the accuracy baseline: y = x @ w^T + b."""
    return jnp.matmul(x, w.T) + bias[None, :]


# ---------------------------------------------------------------------------
# Attention - SII-C (XLM-R transformer hot loop)
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled dot-product attention over [heads, seq, head_dim] arrays."""
    d = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(d))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


# ---------------------------------------------------------------------------
# Misc ops used by the L2 models (also mirrored in rust `fbia::numerics`)
# ---------------------------------------------------------------------------

def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def gelu(x: jax.Array) -> jax.Array:
    # tanh approximation, the deployment-common form
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x ** 3)))


def swish(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def dot_interaction(dense: jax.Array, sparse: jax.Array) -> jax.Array:
    """DLRM dot-product feature interaction (SII-A, [52]).

    dense:  [batch, d]
    sparse: [batch, num_tables, d]
    returns [batch, d + num_pairs]: dense passthrough + upper-triangular
    pairwise dots among {dense} U {sparse features}.
    """
    feats = jnp.concatenate([dense[:, None, :], sparse], axis=1)  # [B, F, D]
    f = feats.shape[1]
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)                  # [B, F, F]
    iu, ju = jnp.triu_indices(f, k=1)
    pairs = z[:, iu, ju]                                          # [B, F*(F-1)/2]
    return jnp.concatenate([dense, pairs], axis=1)
