"""L1 Pallas kernel: SparseLengthsSum (EmbeddingBag sum-pooling).

Hardware adaptation (DESIGN.md S3): on the paper's card SLS runs on the
Vector Cores streaming embedding rows from LPDDR. On a TPU-style target the
natural mapping is: grid over batch blocks, the (small) index/length tensors
staged whole in VMEM, table rows gathered from HBM a block at a time and
accumulated into a VMEM output tile. The embedding dimension is the lane
dimension so every gather-accumulate is a full-width vector op.

interpret=True everywhere: CPU PJRT cannot run Mosaic custom-calls; the
kernel still exercises the exact block decomposition.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BATCH_BLOCK = 8


def _sls_kernel(indices_ref, lengths_ref, table_ref, o_ref, *, max_len: int):
    """One grid step: pool a [block_b, max_len] slice of lookups.

    indices_ref: [block_b, max_len] i32 (VMEM)
    lengths_ref: [block_b] i32 (VMEM)
    table_ref:   [rows, dim] f32 (whole table; rows gathered on demand)
    o_ref:       [block_b, dim] f32
    """
    idx = indices_ref[...]                                   # [Bb, L]
    lens = lengths_ref[...]                                  # [Bb]
    # Gather all candidate rows, then mask-accumulate. The gather is the
    # VMEM-staged equivalent of the Vector Core's row-stream; masking encodes
    # the "partial tensor" contract (tail indices are garbage but unused).
    rows = table_ref[idx]                                    # [Bb, L, D]
    mask = (jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1)
            < lens[:, None]).astype(rows.dtype)              # [Bb, L]
    o_ref[...] = jnp.sum(rows * mask[:, :, None], axis=1)


@functools.partial(jax.jit, static_argnames=("block_b",))
def sls(table: jax.Array, indices: jax.Array, lengths: jax.Array,
        block_b: int = DEFAULT_BATCH_BLOCK) -> jax.Array:
    """Pallas SparseLengthsSum.

    table:   [rows, dim] f32
    indices: [batch, max_len] i32
    lengths: [batch] i32
    returns: [batch, dim] f32
    """
    batch, max_len = indices.shape
    rows, dim = table.shape
    if batch % block_b != 0:
        # pad batch to a block multiple; extra rows pool zero lookups
        pad = block_b - batch % block_b
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        lengths = jnp.pad(lengths, (0, pad))
        out = sls(table, indices, lengths, block_b=block_b)
        return out[:batch]

    grid = (batch // block_b,)
    kernel = functools.partial(_sls_kernel, max_len=max_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, max_len), lambda b: (b, 0)),
            pl.BlockSpec((block_b,), lambda b: (b,)),
            pl.BlockSpec((rows, dim), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, dim), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, dim), table.dtype),
        interpret=True,
    )(indices, lengths, table)


def sls_vmem_bytes(block_b: int, max_len: int, rows: int, dim: int,
                   dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (DESIGN.md S8).

    The table itself streams from HBM; resident blocks are the index slice,
    the gathered row block, and the output tile.
    """
    idx = block_b * max_len * 4
    lens = block_b * 4
    gathered = block_b * max_len * dim * dtype_bytes
    out = block_b * dim * dtype_bytes
    return idx + lens + gathered + out
