"""L1 Pallas kernel: tiled scaled-dot-product attention (XLM-R hot loop).

Hardware adaptation (DESIGN.md S3): the paper runs 72.5% of XLM-R time in
MatMul on the Matrix Engine (Table II). On a TPU-style target the attention
inner loop tiles queries into VMEM-resident blocks; for the short sequences
the paper serves (20-70 tokens, padded buckets <= 128) whole K/V for one head
fit in VMEM, so the kernel grids over (head, query-block) and keeps the
softmax row-local -- no online-softmax pass is needed at these lengths,
which mirrors the paper's choice of plain padded GEMMs over fancier
variable-length schemes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_Q = 32


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One (head, q-block) step.

    q_ref: [1, bq, d]; k_ref/v_ref: [1, s, d]; o_ref: [1, bq, d]
    """
    q = q_ref[0]                       # [bq, d]
    k = k_ref[0]                       # [s, d]
    v = v_ref[0]                       # [s, d]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale       # [bq, s] (MXU)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jax.lax.dot_general(
        probs, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # [bq, d] (MXU)


@functools.partial(jax.jit, static_argnames=("block_q",))
def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              block_q: int = DEFAULT_BLOCK_Q) -> jax.Array:
    """softmax(QK^T/sqrt(d))V over [heads, seq, head_dim] inputs."""
    h, s, d = q.shape
    bq = min(block_q, s)
    if s % bq != 0:
        bq = s  # degenerate: single block (short sequences)
    grid = (h, s // bq)
    scale = 1.0 / float(d) ** 0.5
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((1, s, d), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((1, s, d), lambda hh, qq: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        interpret=True,
    )(q, k, v)


def attention_vmem_bytes(block_q: int, seq: int, head_dim: int) -> int:
    """Static per-step VMEM footprint: Q tile + full K + V + scores + out."""
    return 4 * (block_q * head_dim + 2 * seq * head_dim
                + block_q * seq + block_q * head_dim)
