"""AOT driver: lower every artifact variant to HLO *text* + manifest.json.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifact variants follow the paper's static-shape bucket strategy (SVI-A):
  - DLRM: dense partition at batch {16,32,64} x {fp32,int8}; SLS shards for
    a 6-card node (4 SLS cards x 2 tables); monolithic reference at b32.
  - XLM-R: sequence buckets {32,64,128} x batch {1,4}.
  - CV trunk: batch {1,4}.

Run as: cd python && python -m compile.aot --out-dir ../artifacts
Python runs ONCE at build time; the rust binary is self-contained after.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .models import dlrm as dlrm_mod
from .models import xlmr as xlmr_mod
from .models import cv as cv_mod

DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "i8": jnp.int8, "f16": jnp.float16}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_artifact(fn, specs):
    """jit-lower fn over ShapeDtypeStructs from specs; return HLO text and
    output shape/dtype descriptions."""
    sds = [jax.ShapeDtypeStruct(shape, DTYPES[dt]) for (_, shape, dt, _) in specs]
    lowered = jax.jit(fn).lower(*sds)
    out_tree = jax.eval_shape(fn, *sds)
    outs = [{"shape": list(o.shape), "dtype": _dt_name(o.dtype)} for o in out_tree]
    return to_hlo_text(lowered), outs


def _dt_name(dtype) -> str:
    return {jnp.dtype("float32"): "f32", jnp.dtype("int32"): "i32",
            jnp.dtype("int8"): "i8", jnp.dtype("float16"): "f16"}[jnp.dtype(dtype)]


def build_all(out_dir: str, fast: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}

    dlrm_cfg = dlrm_mod.DlrmConfig()
    xlmr_cfg = xlmr_mod.XlmrConfig()
    cv_cfg = cv_mod.CvConfig()

    jobs = []

    # --- DLRM dense partition: batch x precision ---
    dlrm_batches = [16, 32, 64] if not fast else [32]
    for b in dlrm_batches:
        for quant in (False, True):
            name = f"dlrm_dense_b{b}_{'int8' if quant else 'fp32'}"
            specs = dlrm_mod.dense_specs(dlrm_cfg, b, quant)
            fn = dlrm_mod.make_dense_fn(dlrm_cfg, b, quant)
            jobs.append((name, fn, specs,
                         {"model": "dlrm", "role": "dense", "batch": b,
                          "precision": "int8" if quant else "fp32"}))

    # --- DLRM SLS shards: 4 SLS cards x 2 tables each (Fig. 6 scheme) ---
    sls_cards = 4
    per_card = dlrm_cfg.num_tables // sls_cards
    for b in dlrm_batches:
        for c in range(sls_cards):
            tables = list(range(c * per_card, (c + 1) * per_card))
            name = f"dlrm_sls_shard{c}_b{b}"
            specs = dlrm_mod.sls_shard_specs(dlrm_cfg, tables, b)
            fn = dlrm_mod.make_sls_shard_fn(dlrm_cfg, tables, b)
            jobs.append((name, fn, specs,
                         {"model": "dlrm", "role": "sls", "batch": b,
                          "shard": c, "tables": tables}))

    # --- XLM-R buckets ---
    seqs = [32, 64, 128] if not fast else [32]
    nlp_batches = [1, 4] if not fast else [1]
    for s in seqs:
        for b in nlp_batches:
            name = f"xlmr_s{s}_b{b}"
            specs = xlmr_mod.model_specs(xlmr_cfg, b, s)
            fn = xlmr_mod.make_model_fn(xlmr_cfg, b, s)
            jobs.append((name, fn, specs,
                         {"model": "xlmr", "role": "full", "batch": b, "seq": s}))

    # --- CV trunk ---
    cv_batches = [1, 4] if not fast else [1]
    for b in cv_batches:
        name = f"cv_trunk_b{b}"
        specs = cv_mod.model_specs(cv_cfg, b)
        fn = cv_mod.make_model_fn(cv_cfg, b)
        jobs.append((name, fn, specs,
                     {"model": "cv", "role": "full", "batch": b}))

    for name, fn, specs, meta in jobs:
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        print(f"[aot] lowering {name} ...", flush=True)
        hlo, outs = lower_artifact(fn, specs)
        with open(path, "w") as f:
            f.write(hlo)
        entry = {
            "name": name,
            "file": fname,
            "sha256": hashlib.sha256(hlo.encode()).hexdigest()[:16],
            "inputs": [
                {"name": n, "shape": list(shape), "dtype": dt, "kind": kind}
                for (n, shape, dt, kind) in specs
            ],
            "outputs": outs,
        }
        entry.update(meta)
        manifest["artifacts"].append(entry)
        print(f"[aot]   wrote {fname} ({len(hlo)} chars)", flush=True)

    # model-level metadata the rust side uses for weight generation
    manifest["configs"] = {
        "dlrm": {
            "num_tables": dlrm_cfg.num_tables,
            "rows_per_table": dlrm_cfg.rows_per_table,
            "embed_dim": dlrm_cfg.embed_dim,
            "dense_in": dlrm_cfg.dense_in,
            "bottom_mlp": list(dlrm_cfg.bottom_mlp),
            "top_mlp": list(dlrm_cfg.top_mlp),
            "max_lookups": dlrm_cfg.max_lookups,
            "params": dlrm_cfg.param_count(),
        },
        "xlmr": {
            "layers": xlmr_cfg.layers, "d_model": xlmr_cfg.d_model,
            "heads": xlmr_cfg.heads, "ffn": xlmr_cfg.ffn,
            "vocab": xlmr_cfg.vocab, "max_pos": xlmr_cfg.max_pos,
            "params": xlmr_cfg.param_count(),
        },
        "cv": {
            "image": cv_cfg.image, "classes": cv_cfg.classes,
            "stem_ch": cv_cfg.stem_ch, "groups": cv_cfg.groups,
            "stages": [list(s) for s in cv_cfg.stages],
            "params": cv_cfg.param_count(),
        },
    }

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts -> {mpath}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="single variant per family (CI smoke)")
    args = ap.parse_args()
    build_all(args.out_dir, fast=args.fast)


if __name__ == "__main__":
    main()
